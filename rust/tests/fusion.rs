//! Level fusion: fused vs unfused parity and the O(log n) dispatch bound.
//!
//! The level-order batched pipeline coalesces every tree level's cache
//! misses across nodes into padded fused submissions (B = 64 query rows,
//! per-row data ranges — `KernelBackend::sums_ranged`). Contracts pinned
//! here:
//!
//! 1. A batched sparsifier round at n = 4096 issues O(log n) backend
//!    dispatches total (counted at the backend's execution counter — on
//!    the CPU backends one `calls()` per fused submission, the same unit
//!    a PJRT artifact run pays per padded execution grid).
//! 2. Fused and unfused rounds produce bit-identical sample
//!    probabilities, reverse probabilities and sparsifier graphs.
//! 3. Ragged edges: levels whose rows are not a multiple of B = 64,
//!    single-node levels, trees below the leaf cutoff, and warm-cache
//!    (empty miss set) rounds.
//! 4. The frontier-batched walk engine (`RandomWalker::walk_batch`): a
//!    `cluster_local::same_cluster` query at n = 4096 resolves its
//!    W-walker, T-step walks in O(T · log n) backend executions (not the
//!    sequential O(W · T · log n)), with endpoints bit-identical to the
//!    sequential walker on the same forked streams and TV-close to the
//!    exact Markov chain; W = 1 / warm-cache / tiny-tree edges.
//! 5. The frontier-batched edge engine (`EdgeSampler::sample_batch`) and
//!    the applications on top of it: batched `triangle_weight_estimate`
//!    and `arboricity_estimate` at n = 4096 cost <= 10 · log₂n fused
//!    dispatches for the WHOLE estimate (not O(pool · reps · log n) /
//!    O(m · log n)) and reproduce the sequential estimators bit for bit
//!    from the same seed; W = 1 / tiny-tree / warm-cache edges.
//! 6. The overlapped submission pipeline (`MultiLevelKde::set_overlap`):
//!    double-buffered pack/execute changes wall-clock only — dispatch
//!    counts, samples, probabilities and estimates are bit-identical
//!    with overlap on (default) or off.
//! 7. Cross-round pipelining (`MultiLevelKde::set_cross_round`): the
//!    persistent overlap session that packs round r+1 while round r
//!    executes — across successive `query_points_multi` calls — is also
//!    wall-clock-only: dispatch counts and every value bit-identical
//!    on/off, with the session counters showing real reuse (epochs and
//!    rounds accumulate, zero fallbacks in single-threaded use).
//! 8. Reverse-probe fusion (`EdgeSampler::set_probe_fusion`): a
//!    two-sided edge batch resolves every reverse probability in ONE
//!    extra `query_points_multi` round instead of a second per-level
//!    sweep — >= 1.5x fewer rounds per batch, edges and probabilities
//!    bit-identical on/off.

use std::sync::Arc;

use kde_matrix::apps::arboricity::{arboricity_estimate, arboricity_estimate_batched};
use kde_matrix::apps::cluster_local::{same_cluster, LocalClusterParams};
use kde_matrix::apps::sparsify::sparsify_batched;
use kde_matrix::apps::triangles::{
    triangle_weight_estimate, triangle_weight_estimate_batched, TriangleParams,
};
use kde_matrix::kde::{KdeConfig, KdeCounters, MultiLevelKde};
use kde_matrix::kernel::{dataset::gaussian_mixture, Dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::sampling::{NeighborSample, NeighborSampler, Primitives};
use kde_matrix::util::rng::Rng;

/// A sampler plus its own call-counting backend.
type Rig = (NeighborSampler, Arc<CpuBackend>);
/// (samples, reverse probs, backend dispatches) of one round.
type Round = (Vec<Option<NeighborSample>>, Vec<f64>, u64);

/// Twin samplers over the SAME dataset: independently built (no shared
/// memo cache), one with level fusion disabled, each with its own
/// call-counting backend.
fn twin_samplers(ds: &Arc<Dataset>, cfg: &KdeConfig) -> (Rig, Rig) {
    let mk = |fused: bool| {
        let be = CpuBackend::new();
        let tree = Arc::new(MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            cfg,
            be.clone(),
            KdeCounters::new(),
        ));
        tree.set_fusion(fused);
        (NeighborSampler::new(tree), be)
    };
    (mk(true), mk(false))
}

/// One sampling round + reverse probabilities; returns (samples, reverse
/// probs, backend dispatches spent).
fn run_round(s: &NeighborSampler, be: &CpuBackend, sources: &[usize], seed: u64) -> Round {
    let before = be.calls();
    let samples = s.sample_batch(sources, &mut Rng::new(seed));
    let pairs: Vec<(usize, usize)> = samples
        .iter()
        .enumerate()
        .filter_map(|(w, smp)| smp.as_ref().map(|smp| (smp.neighbor, sources[w])))
        .collect();
    let probs = s.neighbor_prob_batch(&pairs);
    (samples, probs, be.calls() - before)
}

fn assert_rounds_bit_identical(a: &Round, b: &Round) {
    assert_eq!(a.0.len(), b.0.len());
    for (w, (x, y)) in a.0.iter().zip(&b.0).enumerate() {
        match (x, y) {
            (Some(x), Some(y)) => {
                assert_eq!(x.neighbor, y.neighbor, "walker {w} diverged");
                assert_eq!(
                    x.prob.to_bits(),
                    y.prob.to_bits(),
                    "walker {w}: fused prob {} vs unfused {}",
                    x.prob,
                    y.prob
                );
            }
            (None, None) => {}
            (x, y) => panic!("walker {w}: fused {x:?} vs unfused {y:?}"),
        }
    }
    assert_eq!(a.1.len(), b.1.len());
    for (k, (x, y)) in a.1.iter().zip(&b.1).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "reverse prob {k}: {x} vs {y}");
    }
}

#[test]
fn n4096_round_is_olog_n_executions_and_bit_identical() {
    // The acceptance shape: one batched sampling round (descents + reverse
    // probabilities) over n = 4096 must cost O(log n) fused dispatches,
    // while reproducing the unfused path bit for bit.
    let n = 4096usize;
    let t = 64usize;
    let mut rng = Rng::new(2101);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.2, 0.5, &mut rng));
    let ((fused_s, fused_be), (plain_s, plain_be)) = twin_samplers(&ds, &KdeConfig::exact());
    let sources: Vec<usize> = (0..t).map(|k| (k * 61) % n).collect();

    let fused = run_round(&fused_s, &fused_be, &sources, 11);
    let plain = run_round(&plain_s, &plain_be, &sources, 11);
    assert_rounds_bit_identical(&fused, &plain);

    let log2n = (usize::BITS - n.leading_zeros() - 1) as u64; // 12
    let (fused_calls, plain_calls) = (fused.2, plain.2);
    assert!(fused_calls > 0, "round must hit the backend");
    assert!(
        fused_calls <= 10 * log2n,
        "fused round used {fused_calls} dispatches; O(log n) bound is {}",
        10 * log2n
    );
    assert!(
        fused_calls * 2 <= plain_calls,
        "fusion won too little: {plain_calls} unfused -> {fused_calls} fused"
    );
}

#[test]
fn n4096_sparsifier_round_parity_and_execution_count() {
    // Full sparsify_batched round: identical graphs (same RNG stream, same
    // memoized answers) and the same O(log n) dispatch accounting.
    let n = 4096usize;
    let t = 64usize;
    let mut rng = Rng::new(2203);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.2, 0.5, &mut rng));
    let run = |fused: bool| {
        let be = CpuBackend::new();
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be.clone());
        prims.tree.set_fusion(fused);
        let before = be.calls();
        let r = sparsify_batched(&prims, t, &mut Rng::new(17));
        (r, be.calls() - before)
    };
    let (rf, calls_f) = run(true);
    let (rp, calls_p) = run(false);
    assert_eq!(rf.samples, rp.samples);
    assert_eq!(rf.distinct_edges, rp.distinct_edges);
    // Identical edge multisets -> identical Laplacian quadratic forms,
    // bit for bit (same construction order on both paths).
    let x: Vec<f64> = (0..n).map(|i| ((i * 37) % 101) as f64 / 101.0 - 0.5).collect();
    assert_eq!(
        rf.graph.laplacian_quadratic(&x).to_bits(),
        rp.graph.laplacian_quadratic(&x).to_bits(),
        "fused sparsifier diverged from unfused"
    );
    let log2n = (usize::BITS - n.leading_zeros() - 1) as u64;
    assert!(calls_f > 0 && calls_f <= 10 * log2n, "sparsifier round: {calls_f} dispatches");
    assert!(calls_f * 2 <= calls_p, "fusion won too little: {calls_p} -> {calls_f}");
}

#[test]
fn ragged_rows_and_sampling_estimator_parity() {
    // t = 37 walkers (rows never a multiple of B = 64) over exact AND
    // noisy-estimator trees: fused == unfused bit for bit.
    let mut rng = Rng::new(2301);
    let ds = Arc::new(gaussian_mixture(96, 4, 3, 1.2, 0.5, &mut rng));
    for cfg in [
        KdeConfig::exact(),
        KdeConfig {
            kind: kde_matrix::kde::EstimatorKind::Sampling { eps: 0.4, tau: 0.2 },
            leaf_cutoff: 8,
            seed: 0x77,
        },
    ] {
        let ((fused_s, fused_be), (plain_s, plain_be)) = twin_samplers(&ds, &cfg);
        let sources: Vec<usize> = (0..37).map(|k| (k * 13) % 96).collect();
        let fused = run_round(&fused_s, &fused_be, &sources, 4242);
        let plain = run_round(&plain_s, &plain_be, &sources, 4242);
        assert_rounds_bit_identical(&fused, &plain);
        assert!(fused.2 <= plain.2, "fusion must never dispatch more");
    }
}

#[test]
fn tiny_tree_round_dispatches_nothing() {
    // n <= leaf_cutoff: every descent is a single categorical finish
    // (direct rescan, no oracle) — zero backend dispatches either way.
    let mut rng = Rng::new(2401);
    let ds = Arc::new(gaussian_mixture(12, 3, 2, 1.0, 0.5, &mut rng));
    let ((fused_s, fused_be), _) = twin_samplers(&ds, &KdeConfig::exact());
    let sources: Vec<usize> = (0..30).map(|k| k % 12).collect();
    let (samples, _, calls) = run_round(&fused_s, &fused_be, &sources, 5);
    assert_eq!(calls, 0, "leaf-finish rounds need no backend");
    for (w, s) in samples.iter().enumerate() {
        let s = s.expect("n > 1 always samples");
        assert_ne!(s.neighbor, sources[w]);
    }
}

#[test]
fn n4096_cluster_local_walks_are_ot_log_n_executions() {
    // The acceptance shape: one `same_cluster` query (2 * samples walkers,
    // walk_len steps each) through the frontier-batched walk engine must
    // cost O(T · log n) backend dispatches — NOT the sequential
    // O(samples · T · log n) — while its endpoint draws stay the exact
    // per-stream walks (verified bit for bit below).
    let n = 4096usize;
    let mut rng = Rng::new(2601);
    let ds = Arc::new(gaussian_mixture(n, 3, 4, 1.2, 0.5, &mut rng));
    let params = LocalClusterParams {
        walk_len: 8,
        samples: 16, // W = 32 walkers
        threshold_scale: 1.0,
    };
    let (u, w) = (0usize, 1usize);

    // Frontier-batched query on its own counting backend.
    let be = CpuBackend::new();
    let prims =
        Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be.clone());
    let before = be.calls();
    let _ = same_cluster(&prims, u, w, &params, &mut Rng::new(31));
    let fused_calls = be.calls() - before;

    // Sequential twin (fresh tree + backend, pre-batching shape): one
    // descent at a time, walks in the old interleaved order.
    let be_seq = CpuBackend::new();
    let prims_seq =
        Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be_seq.clone());
    let before = be_seq.calls();
    let mut seq_rng = Rng::new(31);
    for _ in 0..params.samples {
        let _ = prims_seq.walker.walk(u, params.walk_len, &mut seq_rng);
        let _ = prims_seq.walker.walk(w, params.walk_len, &mut seq_rng);
    }
    let plain_calls = be_seq.calls() - before;

    let log2n = (usize::BITS - n.leading_zeros() - 1) as u64; // 12
    let bound = 10 * params.walk_len as u64 * log2n;
    assert!(fused_calls > 0, "the walks must hit the backend");
    assert!(
        fused_calls <= bound,
        "frontier walks used {fused_calls} dispatches; O(T log n) bound is {bound}"
    );
    assert!(
        fused_calls * 4 <= plain_calls,
        "frontier batching won too little: {plain_calls} sequential -> {fused_calls} fused"
    );

    // Bit-level endpoint equivalence on the SAME tree: walker k of a batch
    // equals the sequential walk driven by the k-th forked stream.
    let starts: Vec<usize> = (0..48).map(|k| (k * 127) % n).collect();
    let got = prims.walker.walk_batch(&starts, 6, &mut Rng::new(57));
    let mut fork_src = Rng::new(57);
    let forks: Vec<Rng> = starts.iter().map(|_| fork_src.fork()).collect();
    for (k, mut fork) in forks.into_iter().enumerate() {
        assert_eq!(
            got[k],
            prims.walker.walk(starts[k], 6, &mut fork),
            "walker {k} diverged from its stream"
        );
    }
}

#[test]
fn n4096_batched_triangles_is_olog_n_executions_and_bit_identical() {
    // The acceptance shape for the edge-sampling frontier: one batched
    // Theorem 6.17 estimate at n = 4096 resolves ALL of its
    // edge_pool x reps weighted-neighbor descents in <= 10 * log2(n)
    // fused backend dispatches — not the sequential
    // O(pool * reps * log n) — while reproducing the sequential
    // estimator bit for bit from the same seed.
    let n = 4096usize;
    let mut rng = Rng::new(3101);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.2, 0.5, &mut rng));
    let params = TriangleParams { edge_pool: 32, reps: 4 };

    let be_b = CpuBackend::new();
    let prims_b =
        Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be_b.clone());
    let before = be_b.calls();
    let batched = triangle_weight_estimate_batched(&prims_b, &params, &mut Rng::new(47));
    let calls_batched = be_b.calls() - before;

    let be_s = CpuBackend::new();
    let prims_s =
        Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be_s.clone());
    let before = be_s.calls();
    let sequential = triangle_weight_estimate(&prims_s, &params, &mut Rng::new(47));
    let calls_seq = be_s.calls() - before;

    assert_eq!(
        batched.estimate.to_bits(),
        sequential.estimate.to_bits(),
        "batched triangles diverged: {} vs {}",
        batched.estimate,
        sequential.estimate
    );
    assert_eq!(batched.kernel_evals, sequential.kernel_evals);
    let log2n = (usize::BITS - n.leading_zeros() - 1) as u64; // 12
    assert!(calls_batched > 0, "the estimate must hit the backend");
    assert!(
        calls_batched <= 10 * log2n,
        "batched triangles used {calls_batched} dispatches; O(log n) bound is {}",
        10 * log2n
    );
    assert!(
        calls_batched * 2 <= calls_seq,
        "edge-frontier batching won too little: {calls_seq} sequential -> {calls_batched}"
    );

    // Warm-cache replay: the same seed re-walks the same descents purely
    // from the memo cache — zero dispatches, identical estimate.
    let before = be_b.calls();
    let replay = triangle_weight_estimate_batched(&prims_b, &params, &mut Rng::new(47));
    assert_eq!(be_b.calls() - before, 0, "warm replay must not dispatch");
    assert_eq!(replay.estimate.to_bits(), batched.estimate.to_bits());
}

#[test]
fn n4096_batched_arboricity_is_olog_n_executions_and_bit_identical() {
    // Same acceptance shape for Algorithm 6.14: one batched m-edge draw
    // at n = 4096 costs <= 10 * log2(n) fused dispatches and reproduces
    // the sequential estimate (density, subsample, densest set) bit for
    // bit from the same seed.
    let n = 4096usize;
    let m = 64usize;
    let mut rng = Rng::new(3201);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.2, 0.5, &mut rng));

    let be_b = CpuBackend::new();
    let prims_b =
        Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be_b.clone());
    let before = be_b.calls();
    let batched = arboricity_estimate_batched(&prims_b, m, false, &mut Rng::new(53));
    let calls_batched = be_b.calls() - before;

    let be_s = CpuBackend::new();
    let prims_s =
        Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be_s.clone());
    let before = be_s.calls();
    let sequential = arboricity_estimate(&prims_s, m, false, &mut Rng::new(53));
    let calls_seq = be_s.calls() - before;

    assert_eq!(
        batched.density.to_bits(),
        sequential.density.to_bits(),
        "batched arboricity diverged: {} vs {}",
        batched.density,
        sequential.density
    );
    assert_eq!(batched.subsampled_graph_edges, sequential.subsampled_graph_edges);
    assert_eq!(batched.densest_set, sequential.densest_set);
    let log2n = (usize::BITS - n.leading_zeros() - 1) as u64; // 12
    assert!(calls_batched > 0, "the draw must hit the backend");
    assert!(
        calls_batched <= 10 * log2n,
        "batched arboricity used {calls_batched} dispatches; O(log n) bound is {}",
        10 * log2n
    );
    assert!(
        calls_batched * 2 <= calls_seq,
        "edge-frontier batching won too little: {calls_seq} sequential -> {calls_batched}"
    );
}

#[test]
fn edge_batch_w1_and_tiny_tree_edges() {
    // W = 1: a single-edge batch degenerates to the sequential draw (bit
    // for bit, pinned in sampling/edge.rs units) at no worse than a few
    // fused submissions per descent level.
    let mut rng = Rng::new(3301);
    let ds = Arc::new(gaussian_mixture(97, 4, 3, 1.2, 0.5, &mut rng));
    let be = CpuBackend::new();
    let prims = Primitives::build(ds, Kernel::Laplacian, &KdeConfig::exact(), be.clone());
    let before = be.calls();
    let got = prims.edges.sample_batch(1, &mut Rng::new(61));
    let calls = be.calls() - before;
    assert!(got[0].is_some());
    // log2(97) < 7 levels; forward descent + reverse probe, one fused
    // submission each per level.
    assert!(calls <= 2 * 7, "W = 1 edge batch used {calls} dispatches");

    // Tiny tree (n <= leaf_cutoff): every descent is a categorical leaf
    // finish and the reverse probes are leaf factors — zero dispatches.
    let mut rng = Rng::new(3302);
    let ds = Arc::new(gaussian_mixture(12, 3, 2, 1.0, 0.5, &mut rng));
    let be = CpuBackend::new();
    let prims = Primitives::build(ds, Kernel::Laplacian, &KdeConfig::exact(), be.clone());
    let before = be.calls();
    let batch = prims.edges.sample_batch(40, &mut Rng::new(67));
    assert_eq!(be.calls() - before, 0, "leaf-finish edge batch needs no backend");
    for (k, e) in batch.iter().enumerate() {
        let e = e.expect("n > 1 always samples");
        assert_ne!(e.u, e.v, "edge {k} is a self-loop");
        assert!(e.prob > 0.0);
    }
}

#[test]
fn overlap_toggle_round_is_bit_identical() {
    // The double-buffered submission queue must change wall-clock only:
    // same dispatches, same samples, same probabilities, bit for bit,
    // with overlap on (default) or off (the sequential fallback).
    let mut rng = Rng::new(3401);
    let ds = Arc::new(gaussian_mixture(512, 4, 3, 1.2, 0.5, &mut rng));
    let mk = |overlap: bool| {
        let be = CpuBackend::new();
        let tree = Arc::new(MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            be.clone(),
            KdeCounters::new(),
        ));
        tree.set_overlap(overlap);
        (NeighborSampler::new(tree), be)
    };
    let (s_on, be_on) = mk(true);
    let (s_off, be_off) = mk(false);
    assert!(s_on.tree.overlap() && !s_off.tree.overlap());
    let sources: Vec<usize> = (0..96).map(|k| (k * 5) % 512).collect();
    let on = run_round(&s_on, &be_on, &sources, 41);
    let off = run_round(&s_off, &be_off, &sources, 41);
    assert_rounds_bit_identical(&on, &off);
    assert_eq!(on.2, off.2, "overlap must not change the dispatch count");

    // The batched apps ride the same queue: overlap off reproduces the
    // batched triangles estimate exactly.
    let ovl = Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be_on);
    let seq = Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be_off);
    seq.tree.set_overlap(false);
    let params = TriangleParams { edge_pool: 16, reps: 4 };
    let a = triangle_weight_estimate_batched(&ovl, &params, &mut Rng::new(71));
    let b = triangle_weight_estimate_batched(&seq, &params, &mut Rng::new(71));
    assert_eq!(a.estimate.to_bits(), b.estimate.to_bits());
}

#[test]
fn cross_round_session_is_bit_identical_and_dispatch_neutral() {
    // The persistent overlap session threads one warm packer pipeline
    // through successive query_points_multi rounds. Like the per-call
    // double buffer it must change wall-clock only: several consecutive
    // sampling rounds produce bit-identical samples, probabilities and
    // dispatch counts with cross-round pipelining on (default) or off.
    let mut rng = Rng::new(3501);
    let ds = Arc::new(gaussian_mixture(512, 4, 3, 1.2, 0.5, &mut rng));
    let mk = |cross: bool| {
        let be = CpuBackend::new();
        let tree = Arc::new(MultiLevelKde::build(
            ds.clone(),
            Kernel::Laplacian,
            &KdeConfig::exact(),
            be.clone(),
            KdeCounters::new(),
        ));
        tree.set_cross_round(cross);
        (NeighborSampler::new(tree), be)
    };
    let (s_on, be_on) = mk(true);
    let (s_off, be_off) = mk(false);
    assert!(s_on.tree.cross_round() && !s_off.tree.cross_round());
    let sources: Vec<usize> = (0..96).map(|k| (k * 5) % 512).collect();
    // Three successive rounds: the second and third are exactly where the
    // session's cross-call reuse differs from per-call pipelines.
    for seed in [141u64, 143, 145] {
        let on = run_round(&s_on, &be_on, &sources, seed);
        let off = run_round(&s_off, &be_off, &sources, seed);
        assert_rounds_bit_identical(&on, &off);
        assert_eq!(on.2, off.2, "cross-round overlap must not change dispatches");
    }
    // The session really ran: each round opened batch epochs and pushed
    // its fused rounds through the persistent packer, never falling back
    // (a single-threaded caller cannot contend for the session).
    let (epochs, rounds, fallbacks) = s_on.tree.overlap_stats();
    assert!(epochs >= 6, "descent + probe epochs over 3 rounds, got {epochs}");
    assert!(rounds >= 3, "fused rounds ran on the session, got {rounds}");
    assert_eq!(fallbacks, 0, "uncontended rounds never fall back");
    let (_, rounds_off, _) = s_off.tree.overlap_stats();
    assert_eq!(rounds_off, 0, "cross_round(false) never enters the session");
}

#[test]
fn probe_fusion_cuts_rounds_per_batch_and_stays_bit_identical() {
    // Acceptance pin for reverse-probe fusion: a two-sided edge batch at
    // n = 512 costs >= 1.5x fewer query_points_multi rounds with the
    // reverse probe fused into one batched round (L_forward + 1) than
    // with the second per-level sweep (L_forward + L_reverse), while the
    // reported edges and probabilities stay bit-identical.
    let mut rng = Rng::new(3601);
    let ds = Arc::new(gaussian_mixture(512, 4, 3, 1.2, 0.5, &mut rng));
    let mk = || {
        let be = CpuBackend::new();
        Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be)
    };
    let fused = mk();
    let sweep = mk();
    sweep.edges.set_probe_fusion(false);
    assert!(fused.edges.probe_fusion() && !sweep.edges.probe_fusion());

    // Round counting starts after build (DegreeSampler::build issues its
    // own tree traffic).
    let base_fused = fused.tree.multi_calls();
    let base_sweep = sweep.tree.multi_calls();
    let a = fused.edges.sample_batch(24, &mut Rng::new(91));
    let b = sweep.edges.sample_batch(24, &mut Rng::new(91));
    for (k, (x, y)) in a.iter().zip(&b).enumerate() {
        match (x, y) {
            (Some(x), Some(y)) => {
                assert_eq!((x.u, x.v), (y.u, y.v), "edge {k} diverged");
                assert_eq!(
                    x.prob.to_bits(),
                    y.prob.to_bits(),
                    "edge {k}: fused prob {} vs sweep {}",
                    x.prob,
                    y.prob
                );
            }
            (None, None) => {}
            (x, y) => panic!("edge {k}: fused {x:?} vs sweep {y:?}"),
        }
    }
    let rounds_fused = fused.tree.multi_calls() - base_fused;
    let rounds_sweep = sweep.tree.multi_calls() - base_sweep;
    assert!(rounds_fused > 0, "two-sided batch must issue rounds");
    assert!(
        rounds_sweep as f64 >= 1.5 * rounds_fused as f64,
        "probe fusion saved too little: {rounds_sweep} sweep rounds vs {rounds_fused} fused"
    );
}

#[test]
fn walk_batch_endpoint_tv_matches_exact_chain() {
    // Statistical acceptance: batched endpoints are TV-indistinguishable
    // from the exact t-step Markov chain (and therefore from the
    // sequential walker, which samples the same chain).
    let n = 256usize;
    let (start, t) = (5usize, 3usize);
    let mut rng = Rng::new(2701);
    let ds = Arc::new(gaussian_mixture(n, 3, 4, 2.0, 0.4, &mut rng));
    let ((s, _), _) = twin_samplers(&ds, &KdeConfig::exact());
    let walker = kde_matrix::sampling::RandomWalker::new(Arc::new(s));
    // Exact chain: column-stochastic M = A D^{-1}, t applications.
    let mut m = kde_matrix::linalg::Mat::zeros(n, n);
    for j in 0..n {
        let deg = ds.exact_degree(Kernel::Laplacian, j);
        for i in 0..n {
            if i != j {
                m[(i, j)] = Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64 / deg;
            }
        }
    }
    let mut want = vec![0.0f64; n];
    want[start] = 1.0;
    for _ in 0..t {
        want = m.matvec(&want);
    }
    let mut counts = vec![0f64; n];
    let mut wrng = Rng::new(2703);
    let (batch, rounds) = (2_000usize, 30usize);
    for _ in 0..rounds {
        let starts = vec![start; batch];
        for end in walker.walk_batch(&starts, t, &mut wrng) {
            counts[end] += 1.0;
        }
    }
    let tv = kde_matrix::util::stats::tv_distance(&counts, &want);
    assert!(tv < 0.03, "batched endpoint TV {tv} vs exact chain");
}

#[test]
fn walk_batch_edges_single_walker_and_warm_cache() {
    // W = 1 (ragged n = 97 tree): the frontier engine degenerates to the
    // sequential walk, bit for bit, at no worse a dispatch count than one
    // fused submission per descent level.
    let mut rng = Rng::new(2801);
    let ds = Arc::new(gaussian_mixture(97, 4, 3, 1.2, 0.5, &mut rng));
    let ((s, be), _) = twin_samplers(&ds, &KdeConfig::exact());
    let walker = kde_matrix::sampling::RandomWalker::new(Arc::new(s));
    let t = 5usize;
    let before = be.calls();
    let got = walker.walk_batch(&[13], t, &mut Rng::new(71));
    let calls_batch = be.calls() - before;
    let mut fork_src = Rng::new(71);
    let mut fork = fork_src.fork();
    assert_eq!(got[0], walker.walk(13, t, &mut fork), "W = 1 diverged");
    // log2(97) < 7 internal levels, one fused submission each, t steps.
    assert!(
        calls_batch <= (t * 2 * 7) as u64,
        "W = 1 batch used {calls_batch} dispatches"
    );
    // Warm cache: replaying the same batch (same seed) re-walks the same
    // descents from the memo cache — zero dispatches, same endpoints.
    let starts: Vec<usize> = (0..23).map(|k| (k * 11) % 97).collect();
    let first = walker.walk_batch(&starts, t, &mut Rng::new(73));
    let before = be.calls();
    let second = walker.walk_batch(&starts, t, &mut Rng::new(73));
    assert_eq!(be.calls() - before, 0, "warm replay must not dispatch");
    assert_eq!(first, second);
}

#[test]
fn walk_batch_tiny_tree_dispatches_nothing() {
    // n <= leaf_cutoff: every step of every walker is a categorical
    // leaf finish — the whole batch never touches the backend.
    let mut rng = Rng::new(2901);
    let ds = Arc::new(gaussian_mixture(12, 3, 2, 1.0, 0.5, &mut rng));
    let ((s, be), _) = twin_samplers(&ds, &KdeConfig::exact());
    let walker = kde_matrix::sampling::RandomWalker::new(Arc::new(s));
    let starts: Vec<usize> = (0..30).map(|k| k % 12).collect();
    let before = be.calls();
    let ends = walker.walk_batch(&starts, 6, &mut Rng::new(79));
    assert_eq!(be.calls() - before, 0, "leaf-finish walks need no backend");
    for (k, &e) in ends.iter().enumerate() {
        assert!(e < 12, "walker {k} endpoint out of range");
    }
}

#[test]
fn warm_cache_round_dispatches_nothing() {
    // Replaying the same round against a warm memo cache resolves every
    // level from cache hits: the fused plan sees only empty miss sets.
    let mut rng = Rng::new(2501);
    let ds = Arc::new(gaussian_mixture(256, 4, 2, 1.0, 0.5, &mut rng));
    let ((s, be), _) = twin_samplers(&ds, &KdeConfig::exact());
    let sources: Vec<usize> = (0..48).map(|k| (k * 7) % 256).collect();
    let first = run_round(&s, &be, &sources, 99);
    assert!(first.2 > 0);
    let second = run_round(&s, &be, &sources, 99);
    assert_rounds_bit_identical(&first, &second);
    assert_eq!(second.2, 0, "warm replay must not dispatch");
}
