//! Serving-layer contract tests: cross-request coalescing must be
//! invisible in the answers (bit-identical to solo queries) and visible
//! in the dispatch count (fewer fused submissions than solo queries).

use std::sync::Arc;
use std::time::Duration;

use kde_matrix::kde::KdeConfig;
use kde_matrix::kernel::dataset::gaussian_mixture;
use kde_matrix::kernel::{Dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::error::BackendError;
use kde_matrix::server::{KdeServer, OracleRegistry, RegisteredDataset, ServerConfig, ServerReply};
use kde_matrix::util::rng::Rng;

const N: usize = 256;

fn dataset(seed: u64) -> Arc<Dataset> {
    let mut rng = Rng::new(seed);
    Arc::new(gaussian_mixture(N, 4, 3, 1.5, 0.6, &mut rng))
}

/// A registry with one dataset named "web" plus its own backend handle
/// (for dispatch counting). Built from a seed so two calls produce twin
/// trees with independent memo caches — the solo reference must never
/// share a cache with the server under test, or dispatch counts (and
/// cold/warm behavior) contaminate each other.
fn registry(seed: u64) -> (Arc<OracleRegistry>, Arc<RegisteredDataset>, Arc<CpuBackend>) {
    let backend = CpuBackend::new();
    let reg = OracleRegistry::new(backend.clone());
    let entry = reg.register("web", dataset(seed), Kernel::Laplacian, &KdeConfig::exact());
    (reg, entry, backend)
}

#[test]
fn concurrent_density_replies_are_bit_identical_to_solo() {
    let (reg, _, _) = registry(11);
    let (_, solo, _) = registry(11); // twin tree, separate memo cache
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(20),
        ..ServerConfig::default()
    };
    let srv = KdeServer::start(reg, cfg);
    // 8 concurrent clients, distinct points: whatever mix of flushes the
    // timing produces, every reply must equal the solo twin bit for bit.
    let got: Vec<(usize, f64)> = std::thread::scope(|s| {
        (0..8usize)
            .map(|c| {
                let srv = &srv;
                s.spawn(move || {
                    let i = 13 * c + 5;
                    (i, srv.try_query_density("web", i).unwrap())
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (i, v) in got {
        let want = solo.tree.query_point(solo.tree.root(), i);
        assert_eq!(
            v.to_bits(),
            want.to_bits(),
            "coalesced density for point {i} differs from solo"
        );
    }
}

#[test]
fn concurrent_neighbor_replies_are_bit_identical_to_solo_streams() {
    let (reg, _, _) = registry(13);
    let (_, solo, _) = registry(13);
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(20),
        ..ServerConfig::default()
    };
    let srv = KdeServer::start(reg, cfg);
    let got: Vec<(usize, u64, Option<(usize, f64)>)> = std::thread::scope(|s| {
        (0..8usize)
            .map(|c| {
                let srv = &srv;
                s.spawn(move || {
                    let source = 7 * c + 3;
                    let seed = 0xA11CE + c as u64;
                    let reply = srv.try_sample_neighbor("web", source, seed).unwrap();
                    (source, seed, reply.map(|ns| (ns.neighbor, ns.prob)))
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (source, seed, reply) in got {
        // The request's seed defines its whole stream: a solo sample on
        // the twin tree with the same stream must agree exactly.
        let want = solo.sampler.sample(source, &mut Rng::new(seed));
        match (reply, want) {
            (Some((n, p)), Some(w)) => {
                assert_eq!(n, w.neighbor, "neighbor for source {source}");
                assert_eq!(
                    p.to_bits(),
                    w.prob.to_bits(),
                    "sample probability for source {source}"
                );
            }
            (None, None) => {}
            (got, want) => panic!("source {source}: got {got:?}, want {want:?}"),
        }
    }
}

#[test]
fn coalescing_beats_solo_dispatch_count() {
    // Coalesced: 64 distinct cold points accumulate behind a max_batch=64
    // watermark (age watermark effectively off), so the router makes ONE
    // fused submission for all of them.
    let (reg, _, backend) = registry(17);
    let cfg = ServerConfig {
        max_batch: 64,
        max_wait: Duration::from_secs(600),
        ..ServerConfig::default()
    };
    let srv = KdeServer::start(reg, cfg);
    let before = backend.calls();
    let pending: Vec<_> = (0..64usize)
        .map(|i| srv.try_submit_density("web", i).unwrap())
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        match rx.recv().unwrap().unwrap() {
            ServerReply::Density(v) => assert!(v.is_finite(), "point {i}"),
            other => panic!("point {i}: want density, got {other:?}"),
        }
    }
    let coalesced_calls = backend.calls() - before;

    // Solo twin: the same 64 cold points one query at a time — one
    // dispatch each.
    let (_, solo, solo_backend) = registry(17);
    let before = solo_backend.calls();
    for i in 0..64usize {
        solo.tree.query_point(solo.tree.root(), i);
    }
    let solo_calls = solo_backend.calls() - before;

    assert_eq!(coalesced_calls, 1, "64 cold points must fuse into one dispatch");
    assert_eq!(solo_calls, 64, "solo cold queries dispatch once each");
    // The CI serving gate's coalescing floor, pinned here at unit scale.
    assert!(
        solo_calls >= 2 * coalesced_calls,
        "coalescing floor: solo {solo_calls} vs coalesced {coalesced_calls}"
    );
}

#[test]
fn unknown_dataset_is_rejected_with_typed_error() {
    let (reg, _, _) = registry(19);
    let srv = KdeServer::start(reg, ServerConfig::default());
    match srv.try_query_density("not-registered", 0) {
        Err(BackendError::UnknownDataset { name }) => assert_eq!(name, "not-registered"),
        other => panic!("want UnknownDataset, got {other:?}"),
    }
    match srv.try_sample_neighbor("also-missing", 0, 1) {
        Err(e) => assert!(!e.transient(), "UnknownDataset is permanent"),
        Ok(_) => panic!("lookup of an unregistered dataset must fail"),
    }
    // A registered name still works on the same server.
    assert!(srv.try_query_density("web", 0).is_ok());
}

#[test]
fn deadline_flush_answers_partial_batch() {
    // Only 3 requests against a 64-wide batch watermark: the age
    // watermark alone must flush them, promptly and all together.
    let (reg, entry, _) = registry(23);
    let cfg = ServerConfig {
        max_batch: 64,
        max_wait: Duration::from_millis(5),
        ..ServerConfig::default()
    };
    let srv = KdeServer::start(reg, cfg);
    let pending: Vec<_> = [3usize, 9, 27]
        .into_iter()
        .map(|i| (i, srv.try_submit_density("web", i).unwrap()))
        .collect();
    for (i, rx) in pending {
        let reply = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("age watermark must flush a partial batch");
        match reply.unwrap() {
            ServerReply::Density(v) => {
                let want = entry.tree.query_point(entry.tree.root(), i);
                assert_eq!(v.to_bits(), want.to_bits());
            }
            other => panic!("want density, got {other:?}"),
        }
    }
    let flushes = srv.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
    assert!(flushes >= 1, "at least one flush happened");
    assert!(
        srv.metrics.mean_batch_occupancy() < 64.0,
        "partial batch: occupancy must be below the batch watermark"
    );
}

#[test]
fn expired_deadline_gets_timeout_not_late_answer() {
    let (reg, _, _) = registry(29);
    // Router flushes ~20ms after arrival; the request expires after 1ms,
    // so the flush-time deadline check must answer Timeout.
    let cfg = ServerConfig {
        max_batch: 64,
        max_wait: Duration::from_millis(20),
        ..ServerConfig::default()
    };
    let srv = KdeServer::start(reg, cfg);
    let rx = srv
        .try_submit_density_deadline("web", 0, Duration::from_millis(1))
        .unwrap();
    match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
        Err(BackendError::Timeout) => {}
        other => panic!("want Timeout, got {other:?}"),
    }
    assert_eq!(
        srv.metrics.timeouts.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
}

#[test]
fn mixed_kind_flush_serves_both_densities_and_neighbors() {
    let (reg, _, _) = registry(31);
    let (_, solo, _) = registry(31);
    let cfg = ServerConfig {
        max_batch: 6,
        max_wait: Duration::from_millis(50),
        ..ServerConfig::default()
    };
    let srv = KdeServer::start(reg, cfg);
    // Interleave kinds so one flush carries both; each kind keeps its own
    // arrival-order pack.
    let d0 = srv.try_submit_density("web", 40).unwrap();
    let n0 = srv.try_submit_neighbor("web", 41, 7).unwrap();
    let d1 = srv.try_submit_density("web", 42).unwrap();
    let n1 = srv.try_submit_neighbor("web", 43, 8).unwrap();
    let d2 = srv.try_submit_density("web", 44).unwrap();
    let n2 = srv.try_submit_neighbor("web", 45, 9).unwrap();
    for (rx, i) in [(d0, 40usize), (d1, 42), (d2, 44)] {
        match rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap() {
            ServerReply::Density(v) => {
                let want = solo.tree.query_point(solo.tree.root(), i);
                assert_eq!(v.to_bits(), want.to_bits());
            }
            other => panic!("want density, got {other:?}"),
        }
    }
    for (rx, src, seed) in [(n0, 41usize, 7u64), (n1, 43, 8), (n2, 45, 9)] {
        match rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap() {
            ServerReply::Neighbor(got) => {
                let want = solo.sampler.sample(src, &mut Rng::new(seed));
                match (got, want) {
                    (Some(g), Some(w)) => {
                        assert_eq!(g.neighbor, w.neighbor);
                        assert_eq!(g.prob.to_bits(), w.prob.to_bits());
                    }
                    (None, None) => {}
                    (g, w) => panic!("source {src}: got {g:?}, want {w:?}"),
                }
            }
            other => panic!("want neighbor, got {other:?}"),
        }
    }
}
