//! Coordinator end-to-end: the batching KDE service under concurrent
//! client load, on both backends.

use std::sync::Arc;
use std::time::Duration;

use kde_matrix::coordinator::{BatcherConfig, KdeService};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::runtime::pjrt::PjrtBackend;
use kde_matrix::util::rng::Rng;

fn exact(ds: &kde_matrix::kernel::Dataset, k: Kernel, y: &[f32]) -> f64 {
    (0..ds.n).map(|j| k.eval(ds.point(j), y) as f64).sum()
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let mut rng = Rng::new(501);
    let ds = Arc::new(dataset::gaussian_mixture(256, 8, 3, 1.0, 0.5, &mut rng));
    let svc = Arc::new(KdeService::start(
        vec![(Kernel::Laplacian, ds.clone())],
        CpuBackend::new(),
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(300),
            workers: 4,
            ..BatcherConfig::default()
        },
    ));
    let mut handles = Vec::new();
    for c in 0..8u64 {
        let svc = svc.clone();
        let ds = ds.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(600 + c);
            for _ in 0..50 {
                let i = rng.below(ds.n);
                let got = svc.query(0, ds.point(i).to_vec());
                let want = exact(&ds, Kernel::Laplacian, ds.point(i));
                assert!(
                    (got - want).abs() < 1e-6 * (1.0 + want),
                    "client {c}: {got} vs {want}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        svc.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        8 * 50
    );
    // Concurrency should produce real batching.
    assert!(
        svc.metrics.mean_batch_occupancy() > 1.2,
        "occupancy {}",
        svc.metrics.mean_batch_occupancy()
    );
}

#[test]
fn service_on_pjrt_backend() {
    let Ok(pjrt) = PjrtBackend::new("artifacts") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Rng::new(503);
    let ds = Arc::new(dataset::gaussian_mixture(300, 8, 2, 1.0, 0.5, &mut rng));
    let svc = KdeService::start(
        vec![(Kernel::Gaussian, ds.clone())],
        pjrt,
        BatcherConfig::default(),
    );
    for i in [0usize, 100, 299] {
        let got = svc.query(0, ds.point(i).to_vec());
        let want = exact(&ds, Kernel::Gaussian, ds.point(i));
        assert!(
            (got - want).abs() < 1e-3 * (1.0 + want),
            "pjrt service {got} vs {want}"
        );
    }
    println!("pjrt service metrics: {}", svc.metrics.summary());
    svc.shutdown();
}

#[test]
fn throughput_improves_with_batching() {
    // Same load, batch=1 vs batch=64: batched should not be slower.
    let mut rng = Rng::new(505);
    let ds = Arc::new(dataset::gaussian_mixture(512, 8, 3, 1.0, 0.5, &mut rng));
    let load = 256usize;
    let run = |max_batch: usize| -> f64 {
        let svc = Arc::new(KdeService::start(
            vec![(Kernel::Laplacian, ds.clone())],
            CpuBackend::new(),
            BatcherConfig {
                max_batch,
                max_wait: Duration::from_micros(200),
                workers: 2,
                ..BatcherConfig::default()
            },
        ));
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..load)
            .map(|i| svc.submit(0, ds.point(i % ds.n).to_vec()))
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        }
        t0.elapsed().as_secs_f64()
    };
    let t1 = run(1);
    let t64 = run(64);
    println!("batch=1: {t1:.3}s, batch=64: {t64:.3}s");
    assert!(t64 < t1 * 2.0, "batching regressed: {t64} vs {t1}");
}
