//! Scalar vs SIMD microkernel parity: every vtable the host can run must
//! agree with the portable scalar implementation within the documented
//! bounds, on random *and* adversarial inputs (denormals, FAR-padding
//! underflow, huge coordinates), and the tiled backend must produce the
//! same sums/blocks under every `--simd` mode.
//!
//! Documented numerical contract (see `runtime/simd.rs` module docs):
//!
//! * `dot` / `l1`: the SIMD lanes accumulate in a different order (with
//!   FMA), so each implementation is compared against an f64 reference
//!   with a reassociation bound of `4 * n * eps * magnitude`, where
//!   `magnitude` is the sum of absolute term values and `eps = 2^-24`.
//! * `exp_neg` / `map_kernel_sq`: scalar and SIMD evaluate the same
//!   polynomial (shared `kernel::fexp` coefficients). FMA usually only
//!   perturbs the last bits, but near a half-ulp tie in `x * log2(e)` the
//!   fused path can round the reduction integer `j` the other way; both
//!   sides then sit at opposite edges of the polynomial interval, each
//!   within its 5e-6 error envelope, up to ~128 ULPs apart. The contract
//!   is therefore: within 128 ULPs of each other for normal results, and
//!   both within 1e-5 relative of the true `exp` above the subnormal
//!   range. Inputs past the underflow cutoff produce exactly `0.0` on
//!   every path.

use kde_matrix::kernel::{fast_exp_neg, fexp, Kernel, ALL_KERNELS};
use kde_matrix::runtime::backend::KernelBackend;
use kde_matrix::runtime::pjrt::FAR;
use kde_matrix::runtime::simd::{Isa, MicroKernel, SimdMode, ALL_MODES};
use kde_matrix::runtime::tiled::TiledBackend;
use kde_matrix::util::prop::forall;
use kde_matrix::util::rng::Rng;

const EPS: f64 = 5.9604645e-8; // 2^-24, f32 unit roundoff

/// Map an f32 onto the integer line so ULP distance is a subtraction
/// (sign-magnitude -> lexicographic order; -0.0 and +0.0 coincide).
fn ordered(x: f32) -> i64 {
    let i = x.to_bits() as i32 as i64;
    if i < 0 {
        (i32::MIN as i64) - i
    } else {
        i
    }
}

fn ulp_diff(a: f32, b: f32) -> u64 {
    (ordered(a) - ordered(b)).unsigned_abs()
}

fn rand_buf(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

/// Adversarial coordinate values: subnormals, the smallest/largest
/// normals that survive squaring, FAR-padding magnitude, and exact zeros.
fn adversarial_coords() -> Vec<f32> {
    vec![
        0.0,
        -0.0,
        1.0e-41,           // subnormal
        -1.0e-41,          // negative subnormal
        f32::MIN_POSITIVE, // smallest normal
        1.0e-20,
        -3.5e-1,
        1.0,
        87.0,
        -123.456,
        1.0e4,
        FAR, // 1e6: the PJRT data-padding coordinate
        -FAR,
    ]
}

#[test]
fn dot_and_l1_match_f64_reference_within_reassociation_bound() {
    // Lengths straddle every remainder class of the 4/8/16-wide loops.
    let lens = [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 24, 31, 33, 63, 64, 65, 128, 300];
    forall(38, move |rng, case| {
        let n = lens[case % lens.len()];
        let scale = if case % 3 == 0 { 1.0 } else { 10.0f64.powi((case % 7) as i32 - 3) };
        let x = rand_buf(rng, n, scale);
        let y = rand_buf(rng, n, scale);
        let dot_ref: f64 = x.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
        let dot_mag: f64 = x.iter().zip(&y).map(|(&a, &b)| (a as f64 * b as f64).abs()).sum();
        let l1_ref: f64 = x.iter().zip(&y).map(|(&a, &b)| (a as f64 - b as f64).abs()).sum();
        for mk in MicroKernel::available() {
            let tol = 4.0 * (n as f64) * EPS;
            let got_dot = (mk.dot)(&x, &y) as f64;
            assert!(
                (got_dot - dot_ref).abs() <= tol * dot_mag + 1e-30,
                "{:?} dot n={n}: {got_dot} vs ref {dot_ref} (mag {dot_mag})",
                mk.isa
            );
            let got_l1 = (mk.l1)(&x, &y) as f64;
            assert!(
                (got_l1 - l1_ref).abs() <= tol * l1_ref + 1e-30,
                "{:?} l1 n={n}: {got_l1} vs ref {l1_ref}",
                mk.isa
            );
        }
    });
}

#[test]
fn dot_and_l1_handle_adversarial_values() {
    // Denormals, zeros and FAR-scale values in every lane position of a
    // ragged-length vector.
    let coords = adversarial_coords();
    let mut x = Vec::new();
    let mut y = Vec::new();
    for &a in &coords {
        for &b in &coords {
            x.push(a);
            y.push(b);
        }
    }
    // Trailing cuts push the adversarial values through the remainder
    // (non-multiple-of-lane-width) paths as well.
    for cut in [0usize, 1, 3, 7] {
        let xs = &x[..x.len() - cut];
        let ys = &y[..y.len() - cut];
        let dot_ref: f64 = xs.iter().zip(ys).map(|(&a, &b)| a as f64 * b as f64).sum();
        let dot_mag: f64 = xs.iter().zip(ys).map(|(&a, &b)| (a as f64 * b as f64).abs()).sum();
        let l1_ref: f64 = xs.iter().zip(ys).map(|(&a, &b)| (a as f64 - b as f64).abs()).sum();
        for mk in MicroKernel::available() {
            let tol = 4.0 * (xs.len() as f64) * EPS;
            let got_dot = (mk.dot)(xs, ys) as f64;
            assert!(
                (got_dot - dot_ref).abs() <= tol * dot_mag + 1e-30,
                "{:?} adversarial dot cut={cut}: {got_dot} vs {dot_ref}",
                mk.isa
            );
            let got_l1 = (mk.l1)(xs, ys) as f64;
            assert!(
                (got_l1 - l1_ref).abs() <= tol * l1_ref + 1e-30,
                "{:?} adversarial l1 cut={cut}: {got_l1} vs {l1_ref}",
                mk.isa
            );
        }
    }
}

/// Distances to feed the exp map: dense sweep of the live range, the
/// underflow edge, subnormal inputs, negative cancellation residue, and
/// FAR-underflow magnitudes (including values whose `x * log2e`
/// intermediate overflows f32).
fn exp_test_inputs() -> Vec<f32> {
    let mut t = Vec::new();
    let mut v = 0.0f32;
    while v < 100.0 {
        t.push(v);
        v += 0.0417;
    }
    t.extend_from_slice(&[
        0.0,
        -0.0,
        1.0e-41,
        f32::MIN_POSITIVE,
        1.0e-10,
        -1.0e-3, // norm-trick cancellation residue: clamps to exp(0) = 1
        -5.0,
        86.99,
        87.0,
        87.01,
        100.0,
        1.0e4,
        1.0e12,  // FAR sums: d * (1e6)^2
        3.0e38,  // near f32::MAX
        f32::MAX,
        f32::INFINITY,
    ]);
    t
}

#[test]
fn exp_neg_matches_scalar_within_ulps_and_true_exp() {
    let inputs = exp_test_inputs();
    let mut want = vec![0.0f32; inputs.len()];
    let scalar = MicroKernel::select(SimdMode::Scalar).unwrap();
    (scalar.exp_neg)(&inputs, &mut want);
    // The scalar path is itself the documented fast_exp_neg.
    for (&t, &w) in inputs.iter().zip(&want) {
        assert_eq!(w.to_bits(), fast_exp_neg(-t.max(0.0)).to_bits());
    }
    for mk in MicroKernel::available() {
        let mut got = vec![0.0f32; inputs.len()];
        (mk.exp_neg)(&inputs, &mut got);
        for ((&t, &g), &w) in inputs.iter().zip(&got).zip(&want) {
            // Hard underflow must be exact zero on every path.
            if t.max(0.0) > -fexp::UNDERFLOW {
                assert_eq!(g, 0.0, "{:?}: exp(-{t}) must hard-underflow", mk.isa);
                continue;
            }
            // Normal-range results: FMA regrouping, plus the possible
            // one-off range-reduction tie documented in the header.
            if w >= 1.0e-30 {
                assert!(
                    ulp_diff(g, w) <= 128,
                    "{:?}: exp(-{t}) = {g} vs scalar {w} ({} ulps)",
                    mk.isa,
                    ulp_diff(g, w)
                );
                let true_exp = (-(t.max(0.0) as f64)).exp();
                let rel = ((g as f64) - true_exp).abs() / true_exp;
                assert!(rel < 1.0e-5, "{:?}: exp(-{t}) rel err {rel}", mk.isa);
            } else {
                // Deep tail / subnormal fringe: ULPs shrink below the
                // relative envelope here, so bound relative to the scalar
                // value (plus subnormal-rounding headroom).
                assert!(
                    (g as f64 - w as f64).abs() < 1.0e-5 * (w as f64) + 1.0e-42,
                    "{:?}: tail exp(-{t}): {g} vs {w}",
                    mk.isa
                );
            }
        }
    }
}

#[test]
fn map_kernel_sq_parity_on_random_and_adversarial_tiles() {
    let scalar = MicroKernel::select(SimdMode::Scalar).unwrap();
    let adversarial = exp_test_inputs();
    forall(12, move |rng, case| {
        // Random tile sizes crossing the lane boundaries, values spanning
        // the kernel-relevant range, plus the adversarial set appended.
        let n = 1 + rng.below(200);
        let mut dists: Vec<f32> = (0..n)
            .map(|_| ((rng.f64() * 20.0) - 0.001) as f32)
            .collect();
        if case % 2 == 0 {
            dists.extend_from_slice(&adversarial);
        }
        let mut want = vec![0.0f32; dists.len()];
        let mut got = vec![0.0f32; dists.len()];
        for k in ALL_KERNELS {
            (scalar.map_kernel_sq)(k, &dists, &mut want);
            for mk in MicroKernel::available() {
                (mk.map_kernel_sq)(k, &dists, &mut got);
                for ((&t, &g), &w) in dists.iter().zip(&got).zip(&want) {
                    let ok = if w >= 1.0e-30 {
                        ulp_diff(g, w) <= 128
                    } else {
                        (g as f64 - w as f64).abs() < 1.0e-5 * (w as f64) + 1.0e-42
                    };
                    assert!(
                        ok,
                        "{:?} {:?} input {t}: {g} vs scalar {w}",
                        mk.isa, k
                    );
                }
            }
        }
    });
}

/// The AOT shape (d = 64) plus ragged dimensions across every `--simd`
/// mode the host supports: sums and blocks must agree with the forced
/// scalar-microkernel backend within reassociation tolerance.
#[test]
fn sums_and_block_parity_across_simd_modes() {
    let mut rng = Rng::new(6301);
    for &d in &[64usize, 1, 3, 17, 63, 65] {
        let scale = 1.5 / (d as f64).sqrt();
        let (b, m) = (6usize, 260usize);
        let queries = rand_buf(&mut rng, b * d, scale);
        let data = rand_buf(&mut rng, m * d, scale);
        let reference = TiledBackend::with_simd(2, SimdMode::Scalar).unwrap();
        for mode in ALL_MODES {
            let be = match TiledBackend::with_simd(2, mode) {
                Ok(be) => be,
                Err(_) => continue, // ISA not runnable on this host
            };
            for k in ALL_KERNELS {
                let want = reference.sums(k, &queries, &data, d);
                let got = be.sums(k, &queries, &data, d);
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                        "{:?} mode={} d={d}: sums {g} vs {w}",
                        k,
                        mode.name()
                    );
                }
                let want_b = reference.block(k, &queries, &data, d);
                let got_b = be.block(k, &queries, &data, d);
                for (g, w) in got_b.iter().zip(&want_b) {
                    assert!(
                        (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                        "{:?} mode={} d={d}: block {g} vs {w}",
                        k,
                        mode.name()
                    );
                }
            }
        }
    }
}

/// FAR-padding rows must contribute exactly zero mass under every SIMD
/// mode (the PJRT padding contract): padded and unpadded sums agree, and
/// the padded block entries are exact zeros.
#[test]
fn far_underflow_parity_across_simd_modes() {
    let mut rng = Rng::new(6303);
    let d = 16;
    let (b, m_real, m_pad) = (4usize, 40usize, 25usize);
    let queries = rand_buf(&mut rng, b * d, 1.0);
    let real = rand_buf(&mut rng, m_real * d, 1.0);
    let mut padded = real.clone();
    padded.resize(real.len() + m_pad * d, FAR);
    for mode in ALL_MODES {
        let be = match TiledBackend::with_simd(1, mode) {
            Ok(be) => be,
            Err(_) => continue,
        };
        for k in [Kernel::Laplacian, Kernel::Gaussian, Kernel::Exponential] {
            let s_real = be.sums(k, &queries, &real, d);
            let s_pad = be.sums(k, &queries, &padded, d);
            for q in 0..b {
                assert_eq!(
                    s_real[q].to_bits(),
                    s_pad[q].to_bits(),
                    "{:?} mode={}: FAR rows leaked mass (query {q})",
                    k,
                    mode.name()
                );
            }
            let blk = be.block(k, &queries, &padded, d);
            let m_total = m_real + m_pad;
            for q in 0..b {
                for j in m_real..m_total {
                    assert_eq!(
                        blk[q * m_total + j],
                        0.0,
                        "{:?} mode={}: far entry ({q},{j}) nonzero",
                        k,
                        mode.name()
                    );
                }
            }
        }
    }
}

/// The length-mismatch bug: `dot`/`l1` used to silently truncate to the
/// shorter slice. Debug builds must now fail fast.
#[cfg(debug_assertions)]
mod length_asserts {
    use super::*;

    #[test]
    #[should_panic(expected = "mismatched input lengths")]
    fn dot_rejects_mismatched_lengths() {
        let mk = MicroKernel::select(SimdMode::Scalar).unwrap();
        (mk.dot)(&[1.0, 2.0, 3.0], &[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "mismatched input lengths")]
    fn l1_rejects_mismatched_lengths() {
        let mk = MicroKernel::detect();
        (mk.l1)(&[1.0, 2.0, 3.0], &[1.0, 2.0]);
    }
}

#[test]
fn reported_isa_is_consistent() {
    // Every selectable mode reports its own ISA through the backend
    // metadata, and auto matches detection.
    for mode in ALL_MODES {
        if let Ok(be) = TiledBackend::with_simd(1, mode) {
            match mode {
                SimdMode::Auto => {
                    assert_eq!(be.isa(), MicroKernel::detect().isa.name())
                }
                _ => assert_eq!(be.isa(), mode.name()),
            }
        }
    }
    assert_eq!(Isa::Scalar.name(), "scalar");
}
