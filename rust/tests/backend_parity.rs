//! TiledBackend vs CpuBackend parity: the blocked norm-trick backend must
//! agree with the scalar reference on `sums` and `block` for all four
//! kernels across odd dimensions, degenerate shapes (empty / 1-row data)
//! and large-coordinate inputs (the PJRT FAR-padding underflow contract).

use kde_matrix::kernel::{Kernel, ALL_KERNELS};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::pjrt::FAR;
use kde_matrix::runtime::tiled::TiledBackend;
use kde_matrix::util::prop::forall;
use kde_matrix::util::rng::Rng;

/// Sums agree to this relative tolerance (fast-exp rel err ~5e-6 plus the
/// norm trick's f32 cancellation at ||x||^2 ~ 1e3 leaves ~1e-3 headroom).
const SUM_TOL: f64 = 5e-3;
/// Per-element block tolerance.
const BLOCK_TOL: f32 = 2e-3;

fn rand_buf(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn check_parity(queries: &[f32], data: &[f32], d: usize, threads: usize) {
    let cpu = CpuBackend::new();
    let tiled = TiledBackend::with_threads(threads);
    let b = queries.len() / d;
    let m = data.len() / d;
    for k in ALL_KERNELS {
        let want = cpu.sums(k, queries, data, d);
        let got = tiled.sums(k, queries, data, d);
        assert_eq!(got.len(), b);
        for (q, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < SUM_TOL * (1.0 + w.abs()),
                "{:?} b={b} m={m} d={d} threads={threads} query {q}: tiled {g} vs cpu {w}",
                k
            );
        }
        let want_b = cpu.block(k, queries, data, d);
        let got_b = tiled.block(k, queries, data, d);
        assert_eq!(got_b.len(), b * m);
        for i in 0..got_b.len() {
            assert!(
                (got_b[i] - want_b[i]).abs() < BLOCK_TOL * (1.0 + want_b[i].abs()),
                "{:?} d={d} entry {i}: tiled {} vs cpu {}",
                k,
                got_b[i],
                want_b[i]
            );
        }
    }
}

#[test]
fn property_parity_odd_dims_and_shapes() {
    // d = 1, 7, 63 cross sizes that straddle the DTILE=128 tile boundary.
    // Coordinates are scaled ~1/sqrt(d) so kernel values stay O(1) and the
    // comparison actually exercises the kernel map (not just underflow).
    for &d in &[1usize, 7, 63] {
        let scale = 1.5 / (d as f64).sqrt();
        forall(6, move |rng, _| {
            let b = 1 + rng.below(6);
            let m = 1 + rng.below(300);
            let queries = rand_buf(rng, b * d, scale);
            let data = rand_buf(rng, m * d, scale);
            check_parity(&queries, &data, d, 1 + rng.below(4));
        });
    }
}

#[test]
fn parity_single_row_and_empty_data() {
    let mut rng = Rng::new(901);
    for &d in &[1usize, 7, 63] {
        // 1-row data, 1-row query: the smallest legal call.
        let q = rand_buf(&mut rng, d, 1.0);
        let x = rand_buf(&mut rng, d, 1.0);
        check_parity(&q, &x, d, 4);
        // Empty data: sums are exactly zero on both backends.
        let empty: Vec<f32> = Vec::new();
        let cpu = CpuBackend::new();
        let tiled = TiledBackend::with_threads(4);
        for k in ALL_KERNELS {
            assert_eq!(cpu.sums(k, &q, &empty, d), vec![0.0]);
            assert_eq!(tiled.sums(k, &q, &empty, d), vec![0.0]);
            assert!(cpu.block(k, &q, &empty, d).is_empty());
            assert!(tiled.block(k, &q, &empty, d).is_empty());
        }
        // Empty queries.
        assert!(tiled.sums(Kernel::Gaussian, &empty, &x, d).is_empty());
    }
}

#[test]
fn parity_exact_tile_boundaries() {
    // m at exactly the internal tile size and straddling multiples of it.
    let mut rng = Rng::new(903);
    let d = 9;
    for &m in &[127usize, 128, 129, 256, 300] {
        let q = rand_buf(&mut rng, 3 * d, 1.0);
        let x = rand_buf(&mut rng, m * d, 1.0);
        check_parity(&q, &x, d, 3);
    }
}

#[test]
fn far_point_underflow_parity() {
    // The PJRT padding contract: data rows at coordinate FAR=1e6 paired
    // with real (bandwidth-scaled) queries must contribute exactly zero
    // mass on the exponential-family kernels, on BOTH backends, so padded
    // and unpadded calls agree.
    let mut rng = Rng::new(905);
    let d = 16;
    let b = 4;
    let m_real = 40;
    let queries = rand_buf(&mut rng, b * d, 1.0);
    let real = rand_buf(&mut rng, m_real * d, 1.0);
    let mut padded = real.clone();
    for _ in 0..25 * d {
        padded.push(FAR);
    }
    let cpu = CpuBackend::new();
    let tiled = TiledBackend::with_threads(2);
    for k in [Kernel::Laplacian, Kernel::Gaussian, Kernel::Exponential] {
        let cpu_far = cpu.sums(k, &queries, &padded, d);
        let tiled_far = tiled.sums(k, &queries, &padded, d);
        let cpu_real = cpu.sums(k, &queries, &real, d);
        for q in 0..b {
            assert_eq!(
                cpu_far[q], cpu_real[q],
                "{:?}: FAR rows leaked mass on the scalar backend",
                k
            );
            assert!(
                (tiled_far[q] - cpu_real[q]).abs() < SUM_TOL * (1.0 + cpu_real[q]),
                "{:?} query {q}: tiled-with-padding {} vs cpu-unpadded {}",
                k,
                tiled_far[q],
                cpu_real[q]
            );
        }
        // The far block entries themselves underflow to zero.
        let blk = tiled.block(k, &queries, &padded, d);
        let m_total = m_real + 25;
        for q in 0..b {
            for j in m_real..m_total {
                assert_eq!(blk[q * m_total + j], 0.0, "{:?} far entry nonzero", k);
            }
        }
    }
    // Rational quadratic has no exponential underflow; it decays to ~1e-14
    // per far row — verify the backends still agree.
    let cpu_rq = cpu.sums(Kernel::RationalQuadratic, &queries, &padded, d);
    let tiled_rq = tiled.sums(Kernel::RationalQuadratic, &queries, &padded, d);
    for q in 0..b {
        assert!(
            (cpu_rq[q] - tiled_rq[q]).abs() < SUM_TOL * (1.0 + cpu_rq[q].abs()),
            "RQ far parity: {} vs {}",
            tiled_rq[q],
            cpu_rq[q]
        );
    }
}

#[test]
fn eval_counters_agree() {
    let mut rng = Rng::new(907);
    let d = 5;
    let queries = rand_buf(&mut rng, 7 * d, 1.0);
    let data = rand_buf(&mut rng, 33 * d, 1.0);
    let cpu = CpuBackend::new();
    let tiled = TiledBackend::with_threads(3);
    cpu.sums(Kernel::Gaussian, &queries, &data, d);
    tiled.sums(Kernel::Gaussian, &queries, &data, d);
    assert_eq!(cpu.kernel_evals(), 7 * 33);
    assert_eq!(tiled.kernel_evals(), 7 * 33, "per-thread counts must fold");
    assert_eq!(cpu.calls(), 1);
    assert_eq!(tiled.calls(), 1);
}
