//! The batched KDE query pipeline vs the per-query path.
//!
//! Three contracts:
//! 1. `sample_batch` produces *exactly* the samples (neighbor + reported
//!    probability, bit-for-bit) that sequential `sample` calls produce
//!    from the same forked RNG streams — batching changes the evaluation
//!    shape, never the distribution.
//! 2. A 1024-descent sparsifier round through the batched pipeline issues
//!    <= 10% of the backend calls the per-query path issues.
//! 3. The batched sparsifier is still a spectral sparsifier.

use std::sync::Arc;

use kde_matrix::apps::sparsify::{sparsify, sparsify_batched, spectral_error};
use kde_matrix::kde::multilevel::MultiLevelKde;
use kde_matrix::kde::{KdeConfig, KdeCounters};
use kde_matrix::kernel::{dataset::gaussian_mixture, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::sampling::{NeighborSampler, Primitives};
use kde_matrix::util::rng::Rng;

/// Two independently built but identical trees (same dataset, config and
/// deterministic backend), so batched and sequential runs cannot share a
/// memo cache and the comparison is honest.
fn twin_samplers(n: usize, cfg: &KdeConfig, seed: u64) -> (NeighborSampler, NeighborSampler) {
    let mut rng = Rng::new(seed);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.2, 0.5, &mut rng));
    let build = |ds: Arc<kde_matrix::kernel::Dataset>| {
        Arc::new(MultiLevelKde::build(
            ds,
            Kernel::Laplacian,
            cfg,
            CpuBackend::new(),
            KdeCounters::new(),
        ))
    };
    (
        NeighborSampler::new(build(ds.clone())),
        NeighborSampler::new(build(ds)),
    )
}

#[test]
fn batched_descents_match_sequential_bit_for_bit() {
    for cfg in [
        KdeConfig::exact(),
        KdeConfig {
            kind: kde_matrix::kde::EstimatorKind::Sampling { eps: 0.4, tau: 0.2 },
            leaf_cutoff: 8,
            seed: 0x77,
        },
    ] {
        let (batched_s, seq_s) = twin_samplers(96, &cfg, 1201);
        let sources: Vec<usize> = (0..300).map(|k| (k * 13) % 96).collect();
        let batched = batched_s.sample_batch(&sources, &mut Rng::new(4242));
        // Sequential replay: fork per-walker streams from an identical
        // master RNG in the same order sample_batch does.
        let mut master = Rng::new(4242);
        let mut rngs: Vec<Rng> = sources.iter().map(|_| master.fork()).collect();
        for (w, &src) in sources.iter().enumerate() {
            let seq = seq_s.sample(src, &mut rngs[w]);
            match (batched[w], seq) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.neighbor, b.neighbor, "walker {w} diverged");
                    assert_eq!(
                        a.prob.to_bits(),
                        b.prob.to_bits(),
                        "walker {w}: prob {} vs {}",
                        a.prob,
                        b.prob
                    );
                    assert_ne!(a.neighbor, src, "self-sample");
                    // Reported probability matches the deterministic
                    // recomputation on the batched tree too.
                    let recomputed = batched_s.neighbor_prob(src, a.neighbor);
                    assert_eq!(a.prob.to_bits(), recomputed.to_bits());
                }
                (None, None) => {}
                (a, b) => panic!("walker {w}: batched {a:?} vs sequential {b:?}"),
            }
        }
    }
}

#[test]
fn batched_round_issues_under_ten_percent_of_backend_calls() {
    // A 1024-descent sparsifier round, per-query vs batched, on identical
    // primitives. Backend calls are counted at the KernelBackend (every
    // `sums`/`block` dispatch), which is the quantity the AOT/PJRT path
    // pays per execution.
    let n = 256;
    let t = 1024;
    let cfg = KdeConfig::exact();
    let mut rng = Rng::new(1301);
    let ds = Arc::new(gaussian_mixture(n, 4, 3, 1.0, 0.5, &mut rng));

    let be_seq = CpuBackend::new();
    let prims_seq = Primitives::build(ds.clone(), Kernel::Laplacian, &cfg, be_seq.clone());
    let before_seq = be_seq.calls();
    let r_seq = sparsify(&prims_seq, t, &mut Rng::new(7));
    let calls_seq = be_seq.calls() - before_seq;

    let be_bat = CpuBackend::new();
    let prims_bat = Primitives::build(ds.clone(), Kernel::Laplacian, &cfg, be_bat.clone());
    let before_bat = be_bat.calls();
    let r_bat = sparsify_batched(&prims_bat, t, &mut Rng::new(7));
    let calls_bat = be_bat.calls() - before_bat;

    assert_eq!(r_seq.samples, t);
    assert_eq!(r_bat.samples, t);
    assert!(r_bat.distinct_edges > 0);
    assert!(calls_bat > 0, "batched round must still hit the backend");
    assert!(
        calls_bat * 10 <= calls_seq,
        "batched round used {calls_bat} backend calls vs {calls_seq} per-query \
         (need <= 10%)"
    );
    // Both rounds answer the same number of logical KDE queries up to the
    // cache-state difference of their own run (same descents, same memo
    // discipline) — the batched one must not secretly do MORE work.
    assert!(
        r_bat.kde_queries <= r_seq.kde_queries * 2,
        "batched queries {} vs per-query {}",
        r_bat.kde_queries,
        r_seq.kde_queries
    );
}

#[test]
fn batched_sparsifier_is_spectrally_sound() {
    let n = 48;
    let cfg = KdeConfig::exact();
    let mut rng = Rng::new(1401);
    let ds = Arc::new(gaussian_mixture(n, 3, 2, 0.8, 0.5, &mut rng));
    let prims = Primitives::build(ds.clone(), Kernel::Laplacian, &cfg, CpuBackend::new());
    let r = sparsify_batched(&prims, 6_000, &mut rng);
    let err = spectral_error(&ds, Kernel::Laplacian, &r.graph, 20, &mut rng);
    assert!(err < 0.4, "batched sparsifier spectral error {err}");
    assert!(
        r.distinct_edges < n * (n - 1) / 2,
        "must be sparser than complete"
    );
}

#[test]
fn batched_sparsifier_weights_are_consistent() {
    // Every edge weight must equal k(u,v) / (t * (p_u q_uv + p_v q_vu))
    // under the deterministic recomputation of the same tree — i.e. the
    // batched round reports honest probabilities. We verify through the
    // unbiasedness statistic: mean Laplacian quadratic form over repeats
    // approaches the exact one (the test that catches any probability
    // bookkeeping drift in the batched path).
    let n = 24;
    let mut rng = Rng::new(1501);
    let ds = Arc::new(gaussian_mixture(n, 3, 2, 0.8, 0.5, &mut rng));
    let prims = Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), CpuBackend::new());
    let full = kde_matrix::graph::WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let want = full.laplacian_quadratic(&x);
    let runs = 60;
    let mut acc = 0.0;
    for _ in 0..runs {
        let r = sparsify_batched(&prims, 400, &mut rng);
        acc += r.graph.laplacian_quadratic(&x);
    }
    let mean = acc / runs as f64;
    assert!(
        (mean - want).abs() < 0.08 * want,
        "E[x'L'x] = {mean} vs x'Lx = {want}"
    );
}
