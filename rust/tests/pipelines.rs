//! Cross-module integration tests: full paper pipelines on small datasets,
//! CPU backend (fast; PJRT coverage lives in pjrt_parity.rs).

use std::sync::Arc;

use kde_matrix::apps;
use kde_matrix::graph::WGraph;
use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::rng::Rng;
use kde_matrix::util::stats::emd_1d;

fn sampling_cfg() -> KdeConfig {
    KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.25, tau: 0.1 },
        leaf_cutoff: 16,
        seed: 0xBEEF,
    }
}

#[test]
fn sparsify_then_solve_then_cluster() {
    // One primitives build feeding three applications, as a user would.
    let mut rng = Rng::new(401);
    let ds = Arc::new(dataset::nested(128, &mut rng).scaled(3.0));
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Gaussian,
        &sampling_cfg(),
        CpuBackend::new(),
    );
    // 1. sparsify
    let sp = apps::sparsify::sparsify(&prims, 12_000, &mut rng);
    assert!(sp.distinct_edges < 128 * 127 / 2);
    // 2. solve a Laplacian system on the sparsifier
    let mut b: Vec<f64> = (0..128).map(|_| rng.normal()).collect();
    let mean = b.iter().sum::<f64>() / 128.0;
    for v in b.iter_mut() {
        *v -= mean;
    }
    let solve = apps::solver::solve_laplacian(&sp.graph, &b, 1e-8, 4_000);
    assert!(solve.converged, "residual {}", solve.residual);
    // 3. spectral clustering on the sparsifier recovers the two clusters
    let labels = apps::cluster_spectral::spectral_cluster(&sp.graph, 2, &mut rng);
    let acc = apps::cluster_spectral::clustering_accuracy(
        &labels,
        ds.labels.as_ref().unwrap(),
        2,
    );
    assert!(acc > 0.95, "nested clustering accuracy on sparsifier: {acc}");
}

#[test]
fn lra_pipeline_with_sampling_oracle() {
    let mut rng = Rng::new(403);
    let ds = Arc::new(dataset::gaussian_mixture(128, 8, 4, 2.0, 0.4, &mut rng));
    let kmat = apps::lra::materialize_kernel_matrix(&ds, Kernel::Laplacian);
    // Wider-eps sampling oracle: at n = 128 the default config degenerates
    // to a near-full sample and the o(n^2) claim is vacuous.
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.5, tau: 0.3 },
        leaf_cutoff: 16,
        seed: 0xBEEF,
    };
    let r = apps::lra::lra_kde(
        &ds,
        Kernel::Laplacian,
        5,
        8,
        &cfg,
        CpuBackend::new(),
        &mut rng,
    );
    let err = apps::lra::lra_error(&kmat, &r.v);
    let opt = apps::lra::optimal_error(&kmat, 5);
    let frob = kmat.frob_norm_sq();
    assert!(
        err <= opt + 0.2 * frob,
        "additive-error bound: err {err}, opt {opt}, ||K||_F^2 {frob}"
    );
    // KDE path must beat full materialization on kernel evals.
    assert!(
        r.kernel_evals < (128 * 128) as u64,
        "evals {}",
        r.kernel_evals
    );
}

#[test]
fn spectrum_and_eigen_consistency() {
    // The EMD spectrum's largest normalized-Laplacian eigenvalue and the
    // top kernel eigenvalue must both be sane on the same dataset.
    let mut rng = Rng::new(405);
    let ds = Arc::new(dataset::gaussian_mixture(96, 4, 2, 1.0, 0.5, &mut rng));
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig::exact(),
        CpuBackend::new(),
    );
    let params = apps::spectrum::SpectrumParams {
        vertices: 32,
        reps: 200,
        ..Default::default()
    };
    let spec = apps::spectrum::approximate_spectrum(&prims, &params, &mut rng);
    let exact = apps::spectrum::exact_spectrum(&ds, Kernel::Laplacian);
    let emd = emd_1d(&spec.eigenvalues, &exact);
    assert!(emd < 0.25, "spectrum EMD {emd}");

    let eig = apps::eigen_top::eigen_top_direct(&ds, Kernel::Laplacian, 48, 200, &mut rng);
    let eig_exact = apps::eigen_top::exact_top_eigenvalue(&ds, Kernel::Laplacian, &mut rng);
    assert!(
        (eig.lambda - eig_exact).abs() / eig_exact < 0.25,
        "top eig {} vs {eig_exact}",
        eig.lambda
    );
}

#[test]
fn graph_apps_agree_with_exact_baselines() {
    let mut rng = Rng::new(407);
    let ds = Arc::new(dataset::gaussian_mixture(48, 3, 2, 1.5, 0.4, &mut rng));
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig::exact(),
        CpuBackend::new(),
    );
    let g = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);

    // triangles (batched — the default evaluation shape — which equals
    // the sequential estimator bit for bit on the same seed; margin
    // sized for the per-edge forked-stream discipline)
    let tri_exact = g.exact_triangle_weight();
    let tri = apps::triangles::triangle_weight_estimate_batched(
        &prims,
        &apps::triangles::TriangleParams { edge_pool: 600, reps: 48 },
        &mut rng,
    );
    assert!(
        (tri.estimate - tri_exact).abs() / tri_exact < 0.2,
        "triangles {} vs {tri_exact}",
        tri.estimate
    );

    // arboricity (batched, same contract)
    let arb_exact = apps::arboricity::arboricity_exact(&g);
    let arb = apps::arboricity::arboricity_estimate_batched(&prims, 10_000, true, &mut rng);
    assert!(
        (arb.density - arb_exact).abs() / arb_exact < 0.2,
        "arboricity {} vs {arb_exact}",
        arb.density
    );
}

#[test]
fn local_clustering_pipeline() {
    let mut rng = Rng::new(409);
    let ds = Arc::new(dataset::clusterable(128, 6, 2, &mut rng));
    let labels = ds.labels.clone().unwrap();
    let prims = Primitives::build(
        ds,
        Kernel::Laplacian,
        &sampling_cfg(),
        CpuBackend::new(),
    );
    let params = apps::cluster_local::LocalClusterParams::for_n(128);
    let mut correct = 0;
    let trials = 12;
    for t in 0..trials {
        let u = (t * 11) % 128;
        let w = (t * 17 + 1) % 128;
        if u == w {
            correct += 1;
            continue;
        }
        let out = apps::cluster_local::same_cluster(&prims, u, w, &params, &mut rng);
        if out.same_cluster == (labels[u] == labels[w]) {
            correct += 1;
        }
    }
    assert!(correct >= trials - 1, "local clustering {correct}/{trials}");
}

#[test]
fn hbe_estimator_powers_the_primitives() {
    // The HBE oracle slot must work end-to-end (Laplacian kernel).
    let mut rng = Rng::new(411);
    let ds = Arc::new(dataset::gaussian_mixture(96, 4, 1, 0.0, 0.4, &mut rng));
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig {
            kind: EstimatorKind::Hbe { tables: 40, width: 5.0 },
            leaf_cutoff: 16,
            seed: 0xFACE,
        },
        CpuBackend::new(),
    );
    // degrees close to exact
    let mut worst: f64 = 0.0;
    for i in (0..96).step_by(7) {
        let want = ds.exact_degree(Kernel::Laplacian, i);
        let got = prims.degrees.degrees[i];
        worst = worst.max((got - want).abs() / want);
    }
    assert!(worst < 0.35, "HBE degree worst rel err {worst}");
    // sparsifier still consistent (importance weights fix proposal noise)
    let sp = apps::sparsify::sparsify(&prims, 5_000, &mut rng);
    let err =
        apps::sparsify::spectral_error(&ds, Kernel::Laplacian, &sp.graph, 10, &mut rng);
    assert!(err < 0.6, "HBE-driven sparsifier spectral error {err}");
}
