//! Compile-only stub of the internal `xla` PJRT bindings.
//!
//! Mirrors the exact API surface `kde-matrix`'s PJRT engine uses
//! (`rust/src/runtime/pjrt.rs`) so the engine module type-checks without
//! the internal registry. Every entry point is honest about being a stub:
//! [`PjRtClient::cpu`] — the only way to obtain a client — always fails,
//! so no artifact execution path is ever reachable through this crate.
//! Internal builds replace the path dependency with the registry crate of
//! the same name; nothing else changes.

use std::fmt;

/// Stub error: carried by every fallible entry point.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub() -> Error {
        Error(
            "xla stub: the real PJRT bindings are not linked (swap the `xla` \
             path dependency for the internal-registry crate)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The stub constructor always fails, which callers
/// already handle (they degrade to the CPU/tiled backends).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host-side literal (dense array) value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with one argument list; mirrors the real crate's
    /// `execute::<Literal>(&[...])` returning per-device, per-output
    /// buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructor_fails_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        let msg = format!("{err}");
        assert!(msg.contains("xla stub"), "got: {msg}");
        assert!(msg.contains("internal-registry"), "got: {msg}");
    }

    #[test]
    fn literal_construction_is_allowed_but_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
