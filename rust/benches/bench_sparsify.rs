//! Theorem 5.3 / §5.1.1 / §7 sparsification benches:
//!   * sparsifier build cost vs sample budget t,
//!   * spectral error vs t (the eps <-> t trade of Thm 5.3),
//!   * Laplacian solve on sparse vs dense graph (Thm 5.10/5.11),
//!   * the §7.1 edge-reduction numbers.

use std::sync::Arc;

use kde_matrix::apps::{solver, sparsify};
use kde_matrix::graph::WGraph;
use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("bench_sparsify (Thm 5.3 + §5.1.1 + §7)");
    let mut rng = Rng::new(901);
    let n = 1_024usize;
    let ds = Arc::new(dataset::nested(n, &mut rng).scaled(3.0));
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.3, tau: 0.05 },
        leaf_cutoff: 32,
        seed: 9,
    };
    let prims = Primitives::build(ds.clone(), Kernel::Gaussian, &cfg, CpuBackend::new());

    // Error vs sample budget (the eps sweep of Thm 5.3).
    for &t in &[2 * n, 8 * n, 32 * n] {
        let mut edges = 0usize;
        let mut queries = 0u64;
        suite.bench(&format!("sparsify t={t} n={n}"), || {
            let r = sparsify::sparsify(&prims, t, &mut rng);
            edges = r.distinct_edges;
            queries = r.kde_queries;
        });
        let r = sparsify::sparsify(&prims, t, &mut rng);
        let err = sparsify::spectral_error(&ds, Kernel::Gaussian, &r.graph, 12, &mut rng);
        suite.note(&format!(
            "t={t}: {} distinct edges ({:.0}x reduction), spectral err {:.3}, {} fresh queries",
            edges,
            (n * (n - 1) / 2) as f64 / edges.max(1) as f64,
            err,
            queries
        ));
    }

    // Laplacian solve: sparse vs dense (Thm 5.10 role). NOTE: the Nested
    // dataset's minimum kernel value is ~e^-36 — far below any sensible
    // tau floor — so its Laplacian is numerically disconnected and
    // Theorem 5.11's conditioning assumptions (Parameterization 1.2) do
    // not hold there. The solve experiment therefore runs on a mixture
    // with a genuine tau floor.
    let ds_solve = Arc::new(dataset::gaussian_mixture(n, 8, 3, 0.8, 0.5, &mut rng));
    let prims_solve =
        Primitives::build(ds_solve.clone(), Kernel::Laplacian, &cfg, CpuBackend::new());
    let sp = sparsify::sparsify(&prims_solve, 24 * n, &mut rng);
    let full = WGraph::complete_kernel_graph(&ds_solve, Kernel::Laplacian);
    let mut b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mean = b.iter().sum::<f64>() / n as f64;
    for v in b.iter_mut() {
        *v -= mean;
    }
    suite.bench("laplacian_solve sparse", || {
        std::hint::black_box(solver::solve_laplacian(&sp.graph, &b, 1e-8, 4_000));
    });
    suite.bench("laplacian_solve dense", || {
        std::hint::black_box(solver::solve_laplacian(&full, &b, 1e-8, 4_000));
    });
    let err = solver::solve_error_vs_exact(&full, &sp.graph, &b);
    suite.note(&format!(
        "solve on sparsifier vs exact: relative L_G-norm error {err:.4} (Thm 5.11: O(sqrt(eps)))"
    ));
    suite.note(&format!(
        "edges: sparse {} vs dense {} ({:.0}x)",
        sp.graph.num_edges(),
        full.num_edges(),
        full.num_edges() as f64 / sp.graph.num_edges() as f64
    ));
    suite.finish();
}
