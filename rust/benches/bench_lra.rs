//! Fig. 3a/3c regeneration (bench form): rank-vs-error rows and wall time
//! for KDE-LRA vs IS (CountSketch) vs SVD (block power), on the MNIST
//! substitute. The `lra_pipeline` example emits the CSV figures; this
//! target provides the timed comparison rows.

use std::sync::Arc;

use kde_matrix::apps::lra;
use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("bench_lra (Fig. 3)");
    let mut rng = Rng::new(801);
    let n = 768usize;
    let ds = Arc::new(
        dataset::gaussian_mixture(n, 32, 10, 2.0, 0.6, &mut rng)
            .with_median_bandwidth(Kernel::Laplacian, &mut rng),
    );
    let kmat = lra::materialize_kernel_matrix(&ds, Kernel::Laplacian);
    let frob = kmat.frob_norm_sq();
    // FKV tolerates O(1)-factor row-norm accuracy: size the oracle for
    // cost, not precision (see lra_pipeline).
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.5, tau: 0.2 },
        leaf_cutoff: 32,
        seed: 5,
    };

    for &rank in &[5usize, 20] {
        let mut kde_err = 0.0;
        let mut evals = 0;
        suite.bench(&format!("kde_lra rank={rank} n={n}"), || {
            let be = CpuBackend::new();
            let r = lra::lra_kde(&ds, Kernel::Laplacian, rank, 10, &cfg, be, &mut rng);
            kde_err = (lra::lra_error(&kmat, &r.v) / frob).sqrt();
            evals = r.kernel_evals;
        });
        let mut is_err = 0.0;
        suite.bench(&format!("is_lra rank={rank} n={n}"), || {
            let v = lra::lra_countsketch(&kmat, rank, 4 * rank + 10, &mut rng);
            is_err = (lra::lra_error(&kmat, &v) / frob).sqrt();
        });
        let mut svd_err = 0.0;
        suite.bench(&format!("svd_lra rank={rank} n={n}"), || {
            let v = lra::lra_svd(&kmat, rank, 200, &mut rng);
            svd_err = (lra::lra_error(&kmat, &v) / frob).sqrt();
        });
        suite.note(&format!(
            "rank {rank}: rel errs KDE {kde_err:.4} / IS {is_err:.4} / SVD {svd_err:.4}; \
             KDE kernel evals {evals} vs n^2 = {} ({:.1}x fewer)",
            n * n,
            (n * n) as f64 / evals as f64
        ));
    }
    suite.finish();
}
