//! Ablations over the design choices DESIGN.md calls out:
//!
//!  A1. KDE estimator family (naive / sampling / HBE / partition tree)
//!      behind the SAME sparsification pipeline — quality + query cost.
//!  A2. Multi-level tree `leaf_cutoff` — exact-leaf threshold vs the
//!      accuracy/cost trade of neighbor sampling.
//!  A3. Per-(node, query) answer memoization on/off — the §2 consistency
//!      cache (off is emulated by clearing between samples).
//!  A4. One-sided vs two-sided edge sampling probability in Alg 5.1.

use std::sync::Arc;

use kde_matrix::apps::sparsify;
use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("bench_ablations");
    let mut rng = Rng::new(1401);
    let n = 512usize;
    let ds = Arc::new(dataset::gaussian_mixture(n, 8, 3, 1.0, 0.5, &mut rng));

    // ---- A1: estimator family ----
    let kinds: Vec<(&str, EstimatorKind)> = vec![
        ("naive", EstimatorKind::Naive),
        ("sampling eps=.25", EstimatorKind::Sampling { eps: 0.25, tau: 0.1 }),
        ("sampling eps=.5", EstimatorKind::Sampling { eps: 0.5, tau: 0.2 }),
        ("hbe 32tables", EstimatorKind::Hbe { tables: 32, width: 5.0 }),
        ("ptree eps=.1", EstimatorKind::PartitionTree { eps: 0.1 }),
    ];
    for (name, kind) in kinds {
        let cfg = KdeConfig { kind, leaf_cutoff: 16, seed: 0xA1 };
        let t0 = std::time::Instant::now();
        let prims = Primitives::build(ds.clone(), Kernel::Laplacian, &cfg, CpuBackend::new());
        let build_s = t0.elapsed().as_secs_f64();
        let sp = sparsify::sparsify(&prims, 4 * n, &mut rng);
        let err = sparsify::spectral_error(&ds, Kernel::Laplacian, &sp.graph, 10, &mut rng);
        suite.note(&format!(
            "A1 {name:<18}: build {build_s:.2}s, sparsify queries {}, spectral err {err:.3}",
            sp.kde_queries
        ));
    }

    // ---- A2: leaf cutoff ----
    for &cutoff in &[1usize, 8, 32, 128] {
        let cfg = KdeConfig {
            kind: EstimatorKind::Sampling { eps: 0.3, tau: 0.1 },
            leaf_cutoff: cutoff,
            seed: 0xA2,
        };
        let prims = Primitives::build(ds.clone(), Kernel::Laplacian, &cfg, CpuBackend::new());
        let mut tv_samples = Vec::new();
        // neighbor distribution quality for a probe vertex
        let i = 7usize;
        let trials = 6_000;
        let mut counts = vec![1e-300f64; n];
        let t0 = std::time::Instant::now();
        for _ in 0..trials {
            if let Some(s) = prims.neighbors.sample(i, &mut rng) {
                counts[s.neighbor] += 1.0;
            }
        }
        let sample_s = t0.elapsed().as_secs_f64();
        let mut want: Vec<f64> = (0..n)
            .map(|j| {
                if j == i {
                    1e-300
                } else {
                    Kernel::Laplacian.eval(ds.point(i), ds.point(j)) as f64
                }
            })
            .collect();
        let tv = kde_matrix::util::stats::tv_distance(&counts, &want);
        want.clear();
        tv_samples.push(tv);
        suite.note(&format!(
            "A2 leaf_cutoff={cutoff:<4}: neighbor TV {tv:.3}, {:.1}us/sample",
            sample_s * 1e6 / trials as f64
        ));
    }

    // ---- A3: memoization ----
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.3, tau: 0.1 },
        leaf_cutoff: 16,
        seed: 0xA3,
    };
    let prims = Primitives::build(ds.clone(), Kernel::Laplacian, &cfg, CpuBackend::new());
    let q0 = prims.kde_queries();
    for _ in 0..2_000 {
        let i = rng.below(n);
        let _ = prims.neighbors.sample(i, &mut rng);
    }
    let warm = prims.kde_queries() - q0;
    let q1 = prims.kde_queries();
    for _ in 0..2_000 {
        prims.tree.clear_cache(); // emulate no memoization
        let i = rng.below(n);
        let _ = prims.neighbors.sample(i, &mut rng);
    }
    let cold = prims.kde_queries() - q1;
    suite.note(&format!(
        "A3 memoization: {warm} fresh queries warm vs {cold} cold over 2000 samples ({:.1}x saved)",
        cold as f64 / warm.max(1) as f64
    ));

    // ---- A4: one-sided vs two-sided edge probability ----
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig::exact(),
        CpuBackend::new(),
    );
    let mut two_sided_err = 0.0;
    let mut one_sided_err = 0.0;
    {
        let t = 4 * n;
        let r = sparsify::sparsify(&prims, t, &mut rng);
        two_sided_err =
            sparsify::spectral_error(&ds, Kernel::Laplacian, &r.graph, 10, &mut rng);
        // one-sided variant inline
        let mut raw = Vec::new();
        for _ in 0..t {
            if let Some(e) = prims.edges.sample_one_sided(&mut rng) {
                let k_uv = Kernel::Laplacian.eval(ds.point(e.u), ds.point(e.v)) as f64;
                // one-sided prob underestimates by ~2x; the weight formula
                // must use 2*prob to stay unbiased
                raw.push((e.u, e.v, k_uv / (t as f64 * 2.0 * e.prob)));
            }
        }
        let g1 = kde_matrix::graph::WGraph::from_edges(n, raw);
        one_sided_err = sparsify::spectral_error(&ds, Kernel::Laplacian, &g1, 10, &mut rng);
    }
    suite.note(&format!(
        "A4 edge prob: two-sided spectral err {two_sided_err:.3} vs one-sided(2x approx) {one_sided_err:.3}"
    ));
    suite.finish();
}
