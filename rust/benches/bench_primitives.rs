//! Table 2 regeneration: KDE queries + post-processing cost per
//! application primitive, at fixed n and tau.
//!
//! Prints the measured query counts next to the paper's asymptotic rows so
//! the scaling story can be read off directly.

use std::sync::Arc;

use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("bench_primitives (Table 2 + §4 blocks)");
    let mut rng = Rng::new(701);
    let n = 2_048usize;
    let ds = Arc::new(dataset::gaussian_mixture(n, 16, 6, 1.2, 0.5, &mut rng));
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.25, tau: 0.05 },
        leaf_cutoff: 16,
        seed: 3,
    };

    // Primitive build (Alg 4.1 + 4.3): n queries.
    let t0 = std::time::Instant::now();
    let prims = Primitives::build(ds.clone(), Kernel::Laplacian, &cfg, CpuBackend::new());
    suite.note(&format!(
        "build: {} KDE queries in {:.2}s (theory: n = {n})",
        prims.kde_queries(),
        t0.elapsed().as_secs_f64()
    ));

    suite.bench("vertex_sample (Alg 4.6)", || {
        std::hint::black_box(prims.degrees.sample(&mut rng));
    });

    let q_before = prims.kde_queries();
    let mut neighbor_calls = 0u64;
    suite.bench("neighbor_sample (Alg 4.11)", || {
        let i = rng.below(n);
        std::hint::black_box(prims.neighbors.sample(i, &mut rng));
        neighbor_calls += 1;
    });
    suite.note(&format!(
        "neighbor sampling: {:.1} fresh KDE queries/call (theory O(log n) = {:.0}, decaying as cache warms)",
        (prims.kde_queries() - q_before) as f64 / neighbor_calls.max(1) as f64,
        2.0 * (n as f64).log2()
    ));

    suite.bench("edge_sample (Alg 4.13)", || {
        std::hint::black_box(prims.edges.sample(&mut rng));
    });

    suite.bench("random_walk T=16 (Alg 4.16)", || {
        let i = rng.below(n);
        std::hint::black_box(prims.walker.walk(i, 16, &mut rng));
    });

    // Application-level query counts (Table 2 rows).
    let apps: Vec<(&str, Box<dyn FnMut(&mut Rng) -> u64>)> = vec![
        (
            "sparsify t=4n (Thm 5.3)",
            Box::new(|rng: &mut Rng| {
                kde_matrix::apps::sparsify::sparsify(&prims, 4 * n, rng).kde_queries
            }),
        ),
        (
            "arboricity m=2n (Thm 6.15)",
            Box::new(|rng: &mut Rng| {
                kde_matrix::apps::arboricity::arboricity_estimate(&prims, 2 * n, false, rng)
                    .kde_queries
            }),
        ),
        (
            "triangles pool=512 (Thm 6.17)",
            Box::new(|rng: &mut Rng| {
                kde_matrix::apps::triangles::triangle_weight_estimate(
                    &prims,
                    &kde_matrix::apps::triangles::TriangleParams { edge_pool: 512, reps: 8 },
                    rng,
                )
                .kde_queries
            }),
        ),
    ];
    for (name, mut f) in apps {
        let t = std::time::Instant::now();
        let queries = f(&mut rng);
        suite.note(&format!(
            "{name}: {queries} fresh KDE queries, {:.2}s wall",
            t.elapsed().as_secs_f64()
        ));
    }
    suite.finish();
}
