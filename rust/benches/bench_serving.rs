//! Serving-latency load bench for the coalescing KDE server
//! (`kde_matrix::server`): an open-loop arrival process over mixed
//! dataset keys, measured solo (one blocking client, zero-wait flush:
//! one dispatch per query) vs coalesced (concurrent bursty clients
//! behind the batch/age watermark). Emits `BENCH_serving.json` with the
//! p50/p99 latency, throughput and dispatches-per-query series the CI
//! serving leg gates through `scripts/compare_bench.py --serving`:
//! latency/throughput regress against the cached same-ISA baseline, and
//! the coalescing floor (solo dispatches-per-query must beat coalesced
//! by >= 2x) is checked within the fresh run itself.
//!
//! Twin-registry discipline: the solo and coalesced phases each build
//! their own registries (same seeds, so identical trees) over their own
//! `CpuBackend`, and every request in a phase targets a *distinct* point
//! index of its dataset — every density query is a cold memo-cache miss,
//! so the dispatch counter cleanly reads "fused submissions per cold
//! query" with no cross-phase cache contamination.

use std::sync::Arc;
use std::time::{Duration, Instant};

use kde_matrix::kde::KdeConfig;
use kde_matrix::kernel::{dataset, Dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::simd::MicroKernel;
use kde_matrix::server::{KdeServer, OracleRegistry, ServerConfig};
use kde_matrix::util::bench::{fmt_ns, BenchSuite};
use kde_matrix::util::rng::Rng;
use kde_matrix::util::stats::percentile;

const N: usize = 4096;
const D: usize = 16;
const CLIENTS: usize = 8;
const BURST: usize = 16;
const BURSTS_PER_CLIENT: usize = 6;
/// Total requests = CLIENTS * BURSTS_PER_CLIENT * BURST = 768; each
/// dataset receives at most that many, comfortably under N so indices
/// stay distinct (all cold).
const REQUESTS: usize = CLIENTS * BURSTS_PER_CLIENT * BURST;
/// Mean open-loop gap between a client's bursts.
const MEAN_BURST_GAP: Duration = Duration::from_micros(1500);

const DATASETS: [&str; 2] = ["web", "tail"];

/// One scheduled request of the open-loop trace: fire at `at` (offset
/// from the phase start), ask dataset `key` for point `point`.
#[derive(Clone, Copy)]
struct Arrival {
    at: Duration,
    key: &'static str,
    point: usize,
}

/// Pre-generate each client's arrival trace: bursts of back-to-back
/// requests with seeded-exponential gaps between bursts, mixed dataset
/// keys, and globally distinct per-dataset point indices. The trace is
/// fixed before the clock starts — arrival times never depend on reply
/// times, which is what makes the load open-loop.
fn schedule(seed: u64) -> Vec<Vec<Arrival>> {
    let mut rng = Rng::new(seed);
    let mut next_point = [0usize; DATASETS.len()];
    let mut traces: Vec<Vec<Arrival>> = vec![Vec::new(); CLIENTS];
    for trace in traces.iter_mut() {
        let mut at = Duration::ZERO;
        for _ in 0..BURSTS_PER_CLIENT {
            at += MEAN_BURST_GAP.mul_f64(rng.exponential());
            for _ in 0..BURST {
                let k = rng.below(DATASETS.len());
                let point = next_point[k];
                next_point[k] += 1;
                trace.push(Arrival { at, key: DATASETS[k], point });
            }
        }
    }
    assert!(next_point.iter().all(|&c| c <= N), "indices must stay distinct");
    traces
}

fn build_registry(be: Arc<CpuBackend>) -> Arc<OracleRegistry> {
    let reg = OracleRegistry::new(be);
    let mut rng = Rng::new(4242);
    let web: Arc<Dataset> = Arc::new(dataset::gaussian_mixture(N, D, 8, 0.3, 0.35, &mut rng));
    let tail: Arc<Dataset> = Arc::new(dataset::heavy_tailed_mixture(N, D, 4, &mut rng));
    reg.register("web", web, Kernel::Laplacian, &KdeConfig::exact());
    reg.register("tail", tail, Kernel::Gaussian, &KdeConfig::exact());
    reg
}

struct PhaseStats {
    p50_us: f64,
    p99_us: f64,
    throughput_qps: f64,
    dispatches: u64,
    queries: usize,
    mean_flush_occupancy: f64,
}

impl PhaseStats {
    fn dispatches_per_query(&self) -> f64 {
        self.dispatches as f64 / self.queries as f64
    }
}

/// Replay the open-loop trace against a server: every client thread
/// sleeps/spins to its scheduled arrival times, submits asynchronously,
/// and collects its replies afterwards (submission never waits on a
/// reply). Latency is submit-to-reply per request.
fn run_coalesced(traces: &[Vec<Arrival>]) -> PhaseStats {
    let be = CpuBackend::new();
    let reg = build_registry(be.clone());
    let cfg = ServerConfig {
        max_batch: 64,
        max_wait: Duration::from_micros(300),
        queue_cap: 4096,
    };
    let srv = KdeServer::start(reg, cfg);
    let dispatch_base = be.calls();
    let t0 = Instant::now();
    let latencies: Vec<f64> = std::thread::scope(|s| {
        traces
            .iter()
            .map(|trace| {
                let srv = &srv;
                s.spawn(move || {
                    let mut inflight = Vec::with_capacity(trace.len());
                    for a in trace {
                        // Hold the open-loop schedule: sleep coarsely,
                        // spin the last stretch (sleep granularity is
                        // far above the burst gaps).
                        while t0.elapsed() + Duration::from_millis(1) < a.at {
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        while t0.elapsed() < a.at {
                            std::hint::spin_loop();
                        }
                        let sent = Instant::now();
                        let rx = srv
                            .try_submit_density(a.key, a.point)
                            .expect("bench load stays under queue_cap");
                        inflight.push((sent, rx));
                    }
                    inflight
                        .into_iter()
                        .map(|(sent, rx)| {
                            let reply = rx.recv().expect("server replies to every request");
                            reply.expect("bench queries are all valid");
                            sent.elapsed().as_nanos() as f64 / 1e3
                        })
                        .collect::<Vec<f64>>()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect()
    });
    let wall = t0.elapsed();
    let stats = PhaseStats {
        p50_us: percentile(&latencies, 50.0),
        p99_us: percentile(&latencies, 99.0),
        throughput_qps: latencies.len() as f64 / wall.as_secs_f64(),
        dispatches: be.calls() - dispatch_base,
        queries: latencies.len(),
        mean_flush_occupancy: srv.metrics.mean_batch_occupancy(),
    };
    srv.shutdown();
    stats
}

/// The solo baseline: the same request sequence, one blocking client,
/// zero-wait flush (`max_wait = 0`, `max_batch = 1`) — every query is
/// its own flush and its own fused dispatch, the cost the coalescing
/// path amortizes away.
fn run_solo(traces: &[Vec<Arrival>]) -> PhaseStats {
    let be = CpuBackend::new();
    let reg = build_registry(be.clone());
    let cfg = ServerConfig {
        max_batch: 1,
        max_wait: Duration::ZERO,
        queue_cap: 4096,
    };
    let srv = KdeServer::start(reg, cfg);
    let dispatch_base = be.calls();
    let mut latencies = Vec::with_capacity(REQUESTS);
    let t0 = Instant::now();
    for trace in traces {
        for a in trace {
            let sent = Instant::now();
            srv.try_query_density(a.key, a.point)
                .expect("bench queries are all valid");
            latencies.push(sent.elapsed().as_nanos() as f64 / 1e3);
        }
    }
    let wall = t0.elapsed();
    let stats = PhaseStats {
        p50_us: percentile(&latencies, 50.0),
        p99_us: percentile(&latencies, 99.0),
        throughput_qps: latencies.len() as f64 / wall.as_secs_f64(),
        dispatches: be.calls() - dispatch_base,
        queries: latencies.len(),
        mean_flush_occupancy: srv.metrics.mean_batch_occupancy(),
    };
    srv.shutdown();
    stats
}

fn main() {
    let mut suite = BenchSuite::new("bench_serving (coalescing KDE server)");
    let traces = schedule(777);
    let total: usize = traces.iter().map(Vec::len).sum();
    assert_eq!(total, REQUESTS);
    suite.note(&format!(
        "open-loop trace: {CLIENTS} clients x {BURSTS_PER_CLIENT} bursts x {BURST} requests \
         over {} datasets (n = {N}, d = {D}), all points distinct (cold)",
        DATASETS.len()
    ));

    let solo = run_solo(&traces);
    suite.note(&format!(
        "solo:      p50 {} | p99 {} | {:.0} q/s | {} dispatches / {} queries = {:.3} d/q",
        fmt_ns(solo.p50_us * 1e3),
        fmt_ns(solo.p99_us * 1e3),
        solo.throughput_qps,
        solo.dispatches,
        solo.queries,
        solo.dispatches_per_query()
    ));

    let coal = run_coalesced(&traces);
    suite.note(&format!(
        "coalesced: p50 {} | p99 {} | {:.0} q/s | {} dispatches / {} queries = {:.3} d/q \
         (mean flush occupancy {:.1})",
        fmt_ns(coal.p50_us * 1e3),
        fmt_ns(coal.p99_us * 1e3),
        coal.throughput_qps,
        coal.dispatches,
        coal.queries,
        coal.dispatches_per_query(),
        coal.mean_flush_occupancy
    ));
    let ratio = solo.dispatches_per_query() / coal.dispatches_per_query();
    suite.note(&format!("coalescing ratio (solo d/q / coalesced d/q): {ratio:.1}x"));

    let json = format!(
        "{{\n  \"bench\": \"serving\",\n  \"baseline\": \"measured\",\n  \
         \"isa_detected\": \"{}\",\n  \"serving\": {{\n    \
         \"n\": {N}, \"d\": {D}, \"datasets\": {}, \"clients\": {CLIENTS}, \
         \"requests\": {REQUESTS},\n    \
         \"p50_us\": {:.2}, \"p99_us\": {:.2}, \"throughput_qps\": {:.1},\n    \
         \"dispatches\": {}, \"queries\": {}, \"dispatches_per_query\": {:.4},\n    \
         \"mean_flush_occupancy\": {:.2},\n    \
         \"solo_p50_us\": {:.2}, \"solo_p99_us\": {:.2}, \"solo_throughput_qps\": {:.1},\n    \
         \"solo_dispatches_per_query\": {:.4},\n    \
         \"coalescing_ratio\": {:.2}\n  }}\n}}\n",
        MicroKernel::detect().isa.name(),
        DATASETS.len(),
        coal.p50_us,
        coal.p99_us,
        coal.throughput_qps,
        coal.dispatches,
        coal.queries,
        coal.dispatches_per_query(),
        coal.mean_flush_occupancy,
        solo.p50_us,
        solo.p99_us,
        solo.throughput_qps,
        solo.dispatches_per_query(),
        ratio
    );
    match std::fs::write("BENCH_serving.json", &json) {
        Ok(()) => suite.note("wrote BENCH_serving.json"),
        Err(e) => suite.note(&format!("could not write BENCH_serving.json: {e}")),
    }
    suite.finish();
}
