//! Theorem 5.17 (EMD spectrum) and Theorem 5.22 (top eigenvalue) benches:
//! estimate-vs-exact rows with cost accounting, plus the submatrix-size
//! sweep showing n-independence of the eigenvalue estimator.

use std::sync::Arc;

use kde_matrix::apps::{eigen_top, spectrum};
use kde_matrix::kde::KdeConfig;
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;
use kde_matrix::util::stats::emd_1d;

fn main() {
    let mut suite = BenchSuite::new("bench_eigen_spectrum (Thm 5.17 + 5.22)");
    let mut rng = Rng::new(1101);

    // --- Thm 5.22: top eigenvalue, submatrix sweep ---
    let n = 2_048usize;
    let ds = Arc::new(dataset::gaussian_mixture(n, 8, 2, 0.5, 0.5, &mut rng));
    for &t in &[64usize, 256, 512] {
        let mut lam = 0.0;
        suite.bench(&format!("eigen_top direct t={t} n={n}"), || {
            lam = eigen_top::eigen_top_direct(&ds, Kernel::Laplacian, t, 200, &mut rng).lambda;
        });
        suite.note(&format!("t={t}: lambda_est {lam:.2}"));
    }
    let mut lam_noisy = 0.0;
    suite.bench("eigen_top noisy (KDE matvec) t=256", || {
        lam_noisy = eigen_top::eigen_top_noisy(
            &ds,
            Kernel::Laplacian,
            256,
            20,
            16,
            &KdeConfig::exact(),
            CpuBackend::new(),
            &mut rng,
        )
        .lambda;
    });
    // Exact baseline on a subsample of 512 (full n is the quadratic cost
    // the paper avoids; we report it once for the error row).
    let sub = Arc::new(ds.subset(&(0..512).collect::<Vec<_>>()));
    let exact_sub = eigen_top::exact_top_eigenvalue(&sub, Kernel::Laplacian, &mut rng) * n as f64
        / 512.0;
    suite.note(&format!(
        "noisy lambda {lam_noisy:.2}; exact-on-512-scaled {exact_sub:.2}"
    ));

    // --- Thm 5.17: EMD spectrum ---
    let n2 = 384usize;
    let ds2 = Arc::new(dataset::gaussian_mixture(n2, 6, 3, 1.2, 0.5, &mut rng));
    let prims = Primitives::build(
        ds2.clone(),
        Kernel::Laplacian,
        &KdeConfig::exact(),
        CpuBackend::new(),
    );
    let params = spectrum::SpectrumParams {
        vertices: 24,
        reps: 150,
        ..Default::default()
    };
    let mut walks = 0u64;
    suite.bench(&format!("spectrum approx n={n2}"), || {
        let r = spectrum::approximate_spectrum(&prims, &params, &mut rng);
        walks = r.walks;
        std::hint::black_box(r.eigenvalues.len());
    });
    let approx = spectrum::approximate_spectrum(&prims, &params, &mut rng);
    let mut exact = Vec::new();
    suite.bench(&format!("spectrum exact jacobi n={n2}"), || {
        exact = spectrum::exact_spectrum(&ds2, Kernel::Laplacian);
    });
    suite.note(&format!(
        "EMD(approx, exact) = {:.4} using {walks} walks (exact needs the full n^2 graph)",
        emd_1d(&approx.eigenvalues, &exact)
    ));
    suite.finish();
}
