//! Table 1 regeneration: KDE query cost per estimator / kernel / tau,
//! plus the kernel-backend comparison (scalar vs tiled vs tiled+threads)
//! that writes `BENCH_backend.json` so future PRs have a pairs/sec
//! trajectory to regress against (EXPERIMENTS.md §Perf).
//!
//! The paper's Table 1 rows are preprocessing + query complexities; here
//! we measure the realized query time and per-query kernel-evaluation
//! counts of each estimator as n and tau vary. The *shape* to reproduce:
//! naive scales linearly with n; sampling is flat in n with cost
//! ~ 1/(tau eps^2); HBE is flat with cost ~ #tables.

use std::sync::Arc;
use std::time::Instant;

use kde_matrix::apps::sparsify::sparsify_batched;
use kde_matrix::kde::estimators::{NaiveKde, SamplingKde};
use kde_matrix::kde::hbe::HbeKde;
use kde_matrix::kde::{EstimatorKind, Kde, KdeConfig, KdeCounters};
use kde_matrix::kernel::{dataset, Kernel, ALL_KERNELS};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::simd::{MicroKernel, SimdMode};
use kde_matrix::runtime::tiled::TiledBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;

/// Backend sums throughput at the acceptance shape (n = 4096, d = 64,
/// queries = data) and JSON emission for the perf trajectory.
///
/// Series (scripts/compare_bench.py keys on kernel x backend, so labels
/// are stable across hosts; the per-row `isa` records what actually ran):
///
/// * `scalar`          — per-pair scalar reference (`CpuBackend`).
/// * `tiled_1t_scalar` — tiled backend, forced scalar microkernel, one
///   thread: the autovectorized-tiling baseline the SIMD path must beat.
/// * `tiled_1t`        — tiled backend, auto (best) microkernel, one
///   thread: `tiled_1t / tiled_1t_scalar` is the pure SIMD speedup.
/// * `tiled_mt`        — tiled backend, auto microkernel, all cores.
/// Level-fusion dispatch series: one batched sparsifier round (t = 64) at
/// n = 4096 with level fusion on vs off, counted at the backend's
/// dispatch counter — the executions-per-round metric the PJRT path pays
/// per padded artifact run. Emitted as the `fusion` object of
/// `BENCH_backend.json` (tests/fusion.rs pins the O(log n) bound; this
/// series tracks the measured trajectory).
fn fusion_series(rng: &mut Rng) -> String {
    let (n, t, d) = (4096usize, 64usize, 16usize);
    let ds = Arc::new(dataset::gaussian_mixture(n, d, 8, 0.3, 0.35, rng));
    let run = |fused: bool| {
        let be = CpuBackend::new();
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be.clone());
        prims.tree.set_fusion(fused);
        let before = be.calls();
        let start = Instant::now();
        let r = sparsify_batched(&prims, t, &mut Rng::new(17));
        let wall_us = start.elapsed().as_micros();
        assert_eq!(r.samples, t);
        (be.calls() - before, wall_us)
    };
    let (calls_fused, us_fused) = run(true);
    let (calls_unfused, us_unfused) = run(false);
    let log2n = usize::BITS - n.leading_zeros() - 1;
    format!(
        "{{\"n\": {n}, \"t\": {t}, \"d\": {d}, \"log2_n\": {log2n}, \
         \"dispatches_fused\": {calls_fused}, \"dispatches_unfused\": {calls_unfused}, \
         \"round_us_fused\": {us_fused}, \"round_us_unfused\": {us_unfused}}}"
    )
}

/// Frontier-walk dispatch series: one `same_cluster`-shaped walk load
/// (W = 32 walkers x T = 8 steps from two start vertices) at n = 4096,
/// frontier-batched (`RandomWalker::walk_batch`, cross-level packing on)
/// vs sequential walks, counted at the backend dispatch counter. Emitted
/// as the `walk_fusion` object of `BENCH_backend.json`;
/// `scripts/compare_bench.py` gates the O(T log n) bound and the >= 2x
/// win over sequential (tests/fusion.rs pins the same contract).
fn walk_fusion_series(rng: &mut Rng) -> String {
    let (n, t, samples, d) = (4096usize, 8usize, 16usize, 16usize);
    let ds = Arc::new(dataset::gaussian_mixture(n, d, 8, 0.3, 0.35, rng));
    let (calls_batched, us_batched) = {
        let be = CpuBackend::new();
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be.clone());
        let mut starts = vec![0usize; samples];
        starts.resize(2 * samples, 1usize);
        let before = be.calls();
        let start = Instant::now();
        let ends = prims.walker.walk_batch(&starts, t, &mut Rng::new(17));
        let us = start.elapsed().as_micros();
        assert_eq!(ends.len(), 2 * samples);
        (be.calls() - before, us)
    };
    let (calls_seq, us_seq) = {
        let be = CpuBackend::new();
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be.clone());
        let before = be.calls();
        let start = Instant::now();
        let mut r = Rng::new(17);
        for _ in 0..samples {
            std::hint::black_box(prims.walker.walk(0, t, &mut r));
            std::hint::black_box(prims.walker.walk(1, t, &mut r));
        }
        (be.calls() - before, start.elapsed().as_micros())
    };
    let log2n = usize::BITS - n.leading_zeros() - 1;
    format!(
        "{{\"n\": {n}, \"t\": {t}, \"walkers\": {}, \"log2_n\": {log2n}, \
         \"dispatches_batched\": {calls_batched}, \"dispatches_sequential\": {calls_seq}, \
         \"walk_us_batched\": {us_batched}, \"walk_us_sequential\": {us_seq}}}",
        2 * samples
    )
}

/// Edge-frontier dispatch series: one batched Theorem 6.17 triangle
/// estimate (edge_pool = 64 pooled edges x reps = 8 neighbor draws) at
/// n = 4096 through `triangle_weight_estimate_batched` (all descents in
/// one frontier batch) vs the sequential estimator on a twin tree,
/// counted at the backend dispatch counter. Emitted as the `edge_fusion`
/// object of `BENCH_backend.json`; `scripts/compare_bench.py` gates the
/// O(log n) bound and the >= 2x win over sequential (tests/fusion.rs
/// pins the same contract plus bit-identical estimates).
fn edge_fusion_series(rng: &mut Rng) -> String {
    use kde_matrix::apps::triangles::{
        triangle_weight_estimate, triangle_weight_estimate_batched, TriangleParams,
    };
    let (n, d) = (4096usize, 16usize);
    let params = TriangleParams { edge_pool: 64, reps: 8 };
    let ds = Arc::new(dataset::gaussian_mixture(n, d, 8, 0.3, 0.35, rng));
    let (calls_batched, us_batched) = {
        let be = CpuBackend::new();
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be.clone());
        let before = be.calls();
        let start = Instant::now();
        let r = triangle_weight_estimate_batched(&prims, &params, &mut Rng::new(17));
        let us = start.elapsed().as_micros();
        std::hint::black_box(r.estimate);
        (be.calls() - before, us)
    };
    let (calls_seq, us_seq) = {
        let be = CpuBackend::new();
        let prims =
            Primitives::build(ds.clone(), Kernel::Laplacian, &KdeConfig::exact(), be.clone());
        let before = be.calls();
        let start = Instant::now();
        let r = triangle_weight_estimate(&prims, &params, &mut Rng::new(17));
        let us = start.elapsed().as_micros();
        std::hint::black_box(r.estimate);
        (be.calls() - before, us)
    };
    let log2n = usize::BITS - n.leading_zeros() - 1;
    format!(
        "{{\"n\": {n}, \"pool\": {}, \"reps\": {}, \"log2_n\": {log2n}, \
         \"dispatches_batched\": {calls_batched}, \"dispatches_sequential\": {calls_seq}, \
         \"est_us_batched\": {us_batched}, \"est_us_sequential\": {us_seq}}}",
        params.edge_pool, params.reps
    )
}

/// Fused block-row series: LRA-shaped row construction (s = 160 sampled
/// rows against n = 4096 data rows) through planner-chunked
/// `KernelBackend::block_ranged` submissions vs the monolithic `s x n`
/// `block` call. The chunked path holds at most B x n block floats per
/// dispatch (vs s x n) at ceil(s / B) dispatches. Emitted as the
/// `block_fusion` object of `BENCH_backend.json` and gated by
/// `scripts/compare_bench.py` (peak-chunk bound + dispatch shape).
fn block_fusion_series(rng: &mut Rng) -> String {
    use kde_matrix::coordinator::batcher::{plan_level_fusion, FuseJob};
    let (n, s, d) = (4096usize, 160usize, 16usize);
    let ds = dataset::gaussian_mixture(n, d, 8, 0.3, 0.35, rng);
    let flat = ds.flat();
    let picks: Vec<usize> = (0..s).map(|k| (k * 97) % n).collect();
    let mut queries: Vec<f32> = Vec::with_capacity(s * d);
    for &i in &picks {
        queries.extend_from_slice(ds.point(i));
    }
    let be_mono = CpuBackend::new();
    let start = Instant::now();
    let block = be_mono.block(Kernel::Laplacian, &queries, flat, d);
    let us_monolithic = start.elapsed().as_micros();
    let calls_monolithic = be_mono.calls();
    assert_eq!(block.len(), s * n);
    let be_chunk = CpuBackend::new();
    let start = Instant::now();
    let mut peak_rows = 0usize;
    let mut checksum = 0.0f64;
    for sub in plan_level_fusion(&[FuseJob { rows: s, seg_rows: n }], 64, 1024) {
        let mut q: Vec<f32> = Vec::with_capacity(sub.rows.len() * d);
        for &(_, row) in &sub.rows {
            q.extend_from_slice(ds.point(picks[row]));
        }
        let ranges: Vec<(usize, usize)> = vec![(0, n); sub.rows.len()];
        let part = be_chunk.block_ranged(Kernel::Laplacian, &q, flat, d, &ranges);
        peak_rows = peak_rows.max(sub.rows.len());
        checksum += part.iter().map(|&v| v as f64).sum::<f64>();
    }
    let us_chunked = start.elapsed().as_micros();
    let calls_chunked = be_chunk.calls();
    std::hint::black_box(checksum);
    format!(
        "{{\"n\": {n}, \"s\": {s}, \"d\": {d}, \
         \"dispatches_chunked\": {calls_chunked}, \"dispatches_monolithic\": {calls_monolithic}, \
         \"peak_rows_chunked\": {peak_rows}, \"peak_rows_monolithic\": {s}, \
         \"block_us_chunked\": {us_chunked}, \"block_us_monolithic\": {us_monolithic}}}"
    )
}

/// Executor series: per-dispatch overhead of the persistent sharded
/// worker pool (`runtime::pool`) vs per-call `std::thread::scope` spawns
/// at exactly the call shape the batched pipeline produces — many SMALL
/// fused `sums_ranged` submissions (B = 64 query rows against n = 4096
/// data rows) where thread startup is pure overhead. Also snapshots the
/// pool's occupancy/steal counters for the pooled run so the busy /
/// queued_depth / steals series lands in the perf trajectory. Emitted as
/// the `executor` object of `BENCH_backend.json`;
/// `scripts/compare_bench.py` gates the pool-vs-scoped floor
/// (`EXECUTOR_POOL_FLOOR`, default 1.0: the pool must at least match
/// per-dispatch spawning).
fn executor_series(rng: &mut Rng) -> String {
    let (n, b, d, dispatches) = (4096usize, 64usize, 16usize, 256usize);
    let ds = dataset::gaussian_mixture(n, d, 8, 0.3, 0.35, rng);
    let flat = ds.flat();
    let queries: Vec<f32> = flat[..b * d].to_vec();
    let half = n / 2;
    let ranges: Vec<(usize, usize)> = (0..b)
        .map(|q| ((q * 13) % half, half + (q * 29) % half))
        .collect();
    let threads = TiledBackend::default_threads().clamp(2, 8);
    let run = |pooled: bool| {
        let be = TiledBackend::with_threads(threads);
        be.set_pooled(pooled);
        // Warm-up dispatch outside the timed loop: spawns the pool
        // workers (pooled) and pages the buffers in (both).
        std::hint::black_box(be.sums_ranged(Kernel::Laplacian, &queries, flat, d, &ranges));
        let start = Instant::now();
        for _ in 0..dispatches {
            std::hint::black_box(be.sums_ranged(Kernel::Laplacian, &queries, flat, d, &ranges));
        }
        (start.elapsed().as_micros(), be)
    };
    let (us_scoped, _) = run(false);
    let (us_pooled, be) = run(true);
    let m = be
        .pool_metrics()
        .expect("the pooled run must have exercised the pool");
    let speedup = us_scoped as f64 / us_pooled.max(1) as f64;
    format!(
        "{{\"n\": {n}, \"b\": {b}, \"d\": {d}, \"threads\": {threads}, \
         \"dispatches\": {dispatches}, \"dispatch_us_pooled\": {us_pooled}, \
         \"dispatch_us_scoped\": {us_scoped}, \"pooled_speedup\": {speedup:.4}, \
         \"pool_busy_max\": {}, \"pool_queued_max\": {}, \"pool_steals\": {}, \
         \"pool_submitted\": {}, \"pool_inline_runs\": {}}}",
        m.busy_max.load(std::sync::atomic::Ordering::Relaxed),
        m.queued_max.load(std::sync::atomic::Ordering::Relaxed),
        m.steals(),
        m.submitted.load(std::sync::atomic::Ordering::Relaxed),
        m.inline_runs.load(std::sync::atomic::Ordering::Relaxed)
    )
}

fn bench_backends(suite: &mut BenchSuite, rng: &mut Rng) {
    let (n, d) = (4096usize, 64usize);
    let ds = dataset::gaussian_mixture(n, d, 8, 0.3, 0.35, rng);
    let buf = ds.flat();
    let pairs = (n * n) as f64;
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(1);
    let tiled_scalar = TiledBackend::with_simd(1, SimdMode::Scalar)
        .expect("scalar microkernel is always available");
    let backends: Vec<(&str, Arc<dyn KernelBackend>)> = vec![
        ("scalar", CpuBackend::new()),
        ("tiled_1t_scalar", tiled_scalar),
        ("tiled_1t", TiledBackend::with_threads(1)),
        ("tiled_mt", TiledBackend::new()),
    ];
    let mut rows = Vec::new();
    for k in ALL_KERNELS {
        for (label, be) in &backends {
            let mean_ns = suite.bench(
                &format!("backend_sums/{}/{} n={n} d={d}", label, k.name()),
                || {
                    std::hint::black_box(be.sums(k, buf, buf, d));
                },
            );
            let pairs_per_sec = pairs / (mean_ns * 1e-9);
            rows.push(format!(
                "    {{\"kernel\": \"{}\", \"backend\": \"{}\", \"isa\": \"{}\", \
                 \"mean_ns\": {:.0}, \"pairs_per_sec\": {:.4e}}}",
                k.name(),
                label,
                be.isa(),
                mean_ns,
                pairs_per_sec
            ));
        }
    }
    let fusion = fusion_series(rng);
    suite.note(&format!("fusion series: {fusion}"));
    let walk_fusion = walk_fusion_series(rng);
    suite.note(&format!("walk_fusion series: {walk_fusion}"));
    let edge_fusion = edge_fusion_series(rng);
    suite.note(&format!("edge_fusion series: {edge_fusion}"));
    let block_fusion = block_fusion_series(rng);
    suite.note(&format!("block_fusion series: {block_fusion}"));
    let executor = executor_series(rng);
    suite.note(&format!("executor series: {executor}"));
    let json = format!(
        "{{\n  \"bench\": \"backend_sums\",\n  \"n\": {n},\n  \"d\": {d},\n  \
         \"threads_available\": {threads},\n  \"isa_detected\": \"{}\",\n  \
         \"baseline\": \"measured\",\n  \"fusion\": {fusion},\n  \
         \"walk_fusion\": {walk_fusion},\n  \"edge_fusion\": {edge_fusion},\n  \
         \"block_fusion\": {block_fusion},\n  \"executor\": {executor},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        MicroKernel::detect().isa.name(),
        rows.join(",\n")
    );
    match std::fs::write("BENCH_backend.json", &json) {
        Ok(()) => suite.note("wrote BENCH_backend.json"),
        Err(e) => suite.note(&format!("could not write BENCH_backend.json: {e}")),
    }
}

fn main() {
    let mut suite = BenchSuite::new("bench_kde (Table 1)");
    let mut rng = Rng::new(601);

    // Backend comparison first so the JSON lands even if the long Table 1
    // sweep is interrupted.
    bench_backends(&mut suite, &mut rng);

    // The CI bench-regression job only consumes the backend series above;
    // BENCH_BACKENDS_ONLY=1 skips the long Table 1 estimator sweep.
    if std::env::var_os("BENCH_BACKENDS_ONLY").is_some() {
        suite.note("BENCH_BACKENDS_ONLY set: skipping the Table 1 sweep");
        suite.finish();
        return;
    }

    for &n in &[2_048usize, 8_192, 16_384] {
        let ds = Arc::new(dataset::gaussian_mixture(n, 16, 4, 0.6, 0.5, &mut rng));
        let be = CpuBackend::new();
        let ctr = KdeCounters::new();
        let naive = NaiveKde::new(ds.clone(), Kernel::Laplacian, 0, n, be.clone(), ctr.clone());
        let q = ds.point(0).to_vec();
        suite.bench(&format!("naive/query n={n}"), || {
            std::hint::black_box(naive.query(&q));
        });

        for &tau in &[0.1f64, 0.01, 0.001] {
            let cfg = KdeConfig {
                kind: EstimatorKind::Sampling { eps: 0.25, tau },
                leaf_cutoff: 16,
                seed: 1,
            };
            let s = SamplingKde::new(
                ds.clone(),
                Kernel::Laplacian,
                0,
                n,
                &cfg,
                be.clone(),
                ctr.clone(),
                &mut rng,
            );
            suite.bench(&format!("sampling/query n={n} tau={tau}"), || {
                std::hint::black_box(s.query(&q));
            });
            suite.note(&format!(
                "sampling n={n} tau={tau}: sample size {} (theory 4/(tau*eps^2) = {:.0})",
                cfg.sample_size(n),
                4.0 / (tau * 0.25f64 * 0.25)
            ));
        }

        let hbe = HbeKde::new(
            ds.clone(),
            Kernel::Laplacian,
            0,
            n,
            32,
            4.0,
            ctr.clone(),
            &mut rng,
        );
        suite.bench(&format!("hbe/query n={n} tables=32"), || {
            std::hint::black_box(hbe.query(&q));
        });
    }

    // Per-kernel query cost at fixed n (Table 1 kernel column).
    let n = 8_192;
    let ds = Arc::new(dataset::gaussian_mixture(n, 16, 4, 0.6, 0.5, &mut rng));
    let q = ds.point(1).to_vec();
    for k in kde_matrix::kernel::ALL_KERNELS {
        let be = CpuBackend::new();
        let naive = NaiveKde::new(ds.clone(), k, 0, n, be, KdeCounters::new());
        suite.bench(&format!("naive/query kernel={} n={n}", k.name()), || {
            std::hint::black_box(naive.query(&q));
        });
    }
    suite.finish();
}
