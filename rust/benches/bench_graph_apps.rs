//! Graph-application benches: Theorems 6.9 (local clustering), 6.15
//! (arboricity), 6.17 (triangles) — estimate-vs-exact rows plus timing,
//! matching Table 2's graph rows.

use std::sync::Arc;

use kde_matrix::apps::{arboricity, cluster_local, triangles};
use kde_matrix::graph::WGraph;
use kde_matrix::kde::KdeConfig;
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("bench_graph_apps (Thm 6.9 / 6.15 / 6.17)");
    let mut rng = Rng::new(1001);
    let n = 512usize;
    let ds = Arc::new(dataset::gaussian_mixture(n, 8, 3, 1.5, 0.4, &mut rng));
    let prims = Primitives::build(
        ds.clone(),
        Kernel::Laplacian,
        &KdeConfig::exact(),
        CpuBackend::new(),
    );
    let full = WGraph::complete_kernel_graph(&ds, Kernel::Laplacian);

    // --- triangles ---
    let tri_exact = full.exact_triangle_weight();
    let params = triangles::TriangleParams { edge_pool: 512, reps: 16 };
    let mut est = 0.0;
    suite.bench("triangles estimate pool=512", || {
        est = triangles::triangle_weight_estimate(&prims, &params, &mut rng).estimate;
    });
    suite.bench("triangles exact O(nm)", || {
        std::hint::black_box(full.exact_triangle_weight());
    });
    suite.note(&format!(
        "triangles: est {est:.4e} vs exact {tri_exact:.4e} (rel {:.3})",
        (est - tri_exact).abs() / tri_exact
    ));

    // --- arboricity ---
    let mut arb_est = 0.0;
    suite.bench("arboricity estimate m=4n (greedy offline)", || {
        arb_est = arboricity::arboricity_estimate(&prims, 4 * n, false, &mut rng).density;
    });
    let arb_exact = arboricity::arboricity_exact(&full);
    suite.note(&format!(
        "arboricity: est {arb_est:.4} vs exact {arb_exact:.4} (rel {:.3})",
        (arb_est - arb_exact).abs() / arb_exact
    ));
    let mut arb_flow = 0.0;
    suite.bench("arboricity estimate m=4n (flow offline)", || {
        arb_flow = arboricity::arboricity_estimate(&prims, 4 * n, true, &mut rng).density;
    });
    suite.note(&format!("arboricity flow-offline est {arb_flow:.4}"));

    // --- local clustering ---
    let ds_c = Arc::new(dataset::clusterable(n, 6, 2, &mut rng));
    let labels = ds_c.labels.clone().unwrap();
    let prims_c = Primitives::build(
        ds_c,
        Kernel::Laplacian,
        &KdeConfig::exact(),
        CpuBackend::new(),
    );
    let lc = cluster_local::LocalClusterParams::for_n(n);
    let mut correct = 0usize;
    let mut total = 0usize;
    suite.bench("local_cluster same/diff test", || {
        let u = rng.below(n);
        let mut w = rng.below(n);
        while w == u {
            w = rng.below(n);
        }
        let out = cluster_local::same_cluster(&prims_c, u, w, &lc, &mut rng);
        if out.same_cluster == (labels[u] == labels[w]) {
            correct += 1;
        }
        total += 1;
    });
    suite.note(&format!(
        "local clustering accuracy: {correct}/{total} (walks of len {}, {} samples/dist)",
        lc.walk_len, lc.samples
    ));
    suite.finish();
}
