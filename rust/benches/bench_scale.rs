//! Million-point scale-regime bench: dispatches-per-query of the
//! neighbor-sampling descent as n grows (the paper's sub-quadratic
//! claim, read as a per-query execution-count slope).
//!
//! For each n in the series the bench builds a static multi-level tree
//! (Sampling estimators, s = 80 rows per node) over a fresh Gaussian
//! mixture, then
//!
//! * counts backend dispatches over `WALKERS` solo cold descents —
//!   distinct sources, so every (node, source) memo key misses and the
//!   count cleanly reads "fused submissions per cold query". A descent
//!   issues two child queries per internal level and finishes leaves
//!   categorically, so the expected cost is `~2 log2(n / leaf_cutoff)`
//!   dispatches — the ~log n contract `scripts/compare_bench.py --scale`
//!   gates (factor budget `DISPATCH_FACTOR_BUDGET x log2 n` per point,
//!   plus a sub-log growth cap between the two n's);
//! * times the fused batched descent (`sample_batch`) over rotating
//!   distinct-source windows for the latency series.
//!
//! n = 1e5 always runs; the 1e6 point is opt-in via
//! `BENCH_SCALE_MILLION=1` (CI runs it on the nightly leg only — the
//! tree holds ~2n nodes and the build dominates wall time). Emits
//! `BENCH_scale.json` for the CI scale leg.

use std::sync::Arc;
use std::time::Instant;

use kde_matrix::kde::{EstimatorKind, KdeConfig, KdeCounters, MultiLevelKde};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::simd::MicroKernel;
use kde_matrix::sampling::NeighborSampler;
use kde_matrix::util::bench::BenchSuite;
use kde_matrix::util::rng::Rng;

const D: usize = 4;
const WALKERS: usize = 64;
const LEAF_CUTOFF: usize = 16;
/// Per-point within-run gate: dispatches_per_query <= this factor times
/// log2(n). Mirrored by `SCALE_DISPATCH_FACTOR` in compare_bench.py.
const DISPATCH_FACTOR_BUDGET: f64 = 4.0;

struct ScalePoint {
    n: usize,
    log2_n: f64,
    build_ms: f64,
    dispatches: u64,
    dispatches_per_query: f64,
    batch_mean_ns: f64,
}

fn run_scale(n: usize, suite: &mut BenchSuite) -> ScalePoint {
    let be = CpuBackend::new();
    let mut rng = Rng::new(0x5CA1E ^ n as u64);
    let t0 = Instant::now();
    let ds = Arc::new(dataset::gaussian_mixture(n, D, 8, 1.0, 0.5, &mut rng));
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.5, tau: 0.2 },
        leaf_cutoff: LEAF_CUTOFF,
        seed: 0x5EED,
    };
    let tree = Arc::new(MultiLevelKde::build(
        ds,
        Kernel::Laplacian,
        &cfg,
        be.clone(),
        KdeCounters::new(),
    ));
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    suite.note(&format!(
        "n = {n}: built {} nodes in {build_ms:.0} ms",
        tree.num_nodes()
    ));
    let sampler = NeighborSampler::new(tree);

    // Cold dispatch count: WALKERS solo descents from sources spread over
    // [0, n) — all distinct, every memo key cold.
    let stride = n / WALKERS;
    let base = be.calls();
    let mut srng = Rng::new(0xC01D ^ n as u64);
    for w in 0..WALKERS {
        let src = w * stride + stride / 2;
        let _ = sampler.sample(src, &mut srng);
    }
    let dispatches = be.calls() - base;
    let dispatches_per_query = dispatches as f64 / WALKERS as f64;
    let log2_n = (n as f64).log2();
    suite.note(&format!(
        "n = {n}: {dispatches} dispatches / {WALKERS} cold descents = {dispatches_per_query:.2} \
         d/q (budget {:.1} = {DISPATCH_FACTOR_BUDGET} x log2 n)",
        DISPATCH_FACTOR_BUDGET * log2_n
    ));
    assert!(
        dispatches_per_query <= DISPATCH_FACTOR_BUDGET * log2_n,
        "scale regression: {dispatches_per_query:.2} dispatches/query exceeds \
         {DISPATCH_FACTOR_BUDGET} x log2({n})"
    );

    // Latency of the fused batched descent, rotating distinct-source
    // windows so each round mixes warm structure with fresh sources.
    let mut round = 0usize;
    let batch_mean_ns = suite.bench(&format!("neighbor_sample_batch/n={n}/W={WALKERS}"), || {
        let sources: Vec<usize> = (0..WALKERS)
            .map(|k| (round * WALKERS + k * 31 + 1) % n)
            .collect();
        round += 1;
        let mut r = Rng::new(round as u64);
        let _ = sampler.sample_batch(&sources, &mut r);
    });

    ScalePoint { n, log2_n, build_ms, dispatches, dispatches_per_query, batch_mean_ns }
}

fn main() {
    let mut suite = BenchSuite::new("bench_scale (n-scaling of the sampling descent)");
    let mut ns = vec![100_000usize];
    let million = std::env::var("BENCH_SCALE_MILLION").is_ok_and(|v| v == "1");
    if million {
        ns.push(1_000_000);
    } else {
        suite.note("n = 1e6 point skipped (set BENCH_SCALE_MILLION=1 to run it)");
    }
    let points: Vec<ScalePoint> = ns.iter().map(|&n| run_scale(n, &mut suite)).collect();

    if let [a, b] = points.as_slice() {
        let growth = b.dispatches_per_query / a.dispatches_per_query;
        let log_growth = b.log2_n / a.log2_n;
        suite.note(&format!(
            "growth {}k -> {}k: d/q x{growth:.2} vs log-budget x{:.2}",
            a.n / 1000,
            b.n / 1000,
            log_growth * 1.5
        ));
    }

    let series: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{ \"n\": {}, \"log2_n\": {:.3}, \"walkers\": {WALKERS}, \
                 \"dispatches\": {}, \"dispatches_per_query\": {:.4}, \
                 \"build_ms\": {:.1}, \"batch_mean_ns\": {:.0} }}",
                p.n, p.log2_n, p.dispatches, p.dispatches_per_query, p.build_ms, p.batch_mean_ns
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"scale\",\n  \"baseline\": \"measured\",\n  \
         \"isa_detected\": \"{}\",\n  \"scale\": {{\n    \
         \"d\": {D}, \"leaf_cutoff\": {LEAF_CUTOFF}, \"eps\": 0.5, \"tau\": 0.2,\n    \
         \"dispatch_factor_budget\": {DISPATCH_FACTOR_BUDGET},\n    \
         \"series\": [\n{}\n    ]\n  }}\n}}\n",
        MicroKernel::detect().isa.name(),
        series.join(",\n")
    );
    match std::fs::write("BENCH_scale.json", &json) {
        Ok(()) => suite.note("wrote BENCH_scale.json"),
        Err(e) => suite.note(&format!("could not write BENCH_scale.json: {e}")),
    }
    suite.finish();
}
