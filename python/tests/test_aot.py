"""AOT artifact tests: the lowered HLO text is well-formed and has the
shapes the Rust runtime expects."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("kind", ref.KERNELS)
def test_lowered_hlo_text_parses(kind):
    text = aot.lower_entry(model.kde_sums_fn(kind, b=8, m=64, d=4))
    assert "HloModule" in text
    assert "f32[8,4]" in text and "f32[64,4]" in text


@pytest.mark.parametrize("kind", ref.KERNELS)
def test_lowered_ranged_hlo_text_parses(kind):
    b, m, d = 8, 64, 4
    args = model.example_args_ranged(b=b, m=m, d=d)
    text = aot.lower_entry(model.kde_sums_ranged_fn(kind, b=b, m=m, d=d), args)
    assert "HloModule" in text
    assert "f32[8,4]" in text and "f32[64,4]" in text
    assert "s32[8]" in text, "per-row range operands missing"


@pytest.mark.parametrize("kind", ref.KERNELS)
def test_lowered_block_ranged_hlo_text_parses(kind):
    b, m, d = 8, 64, 4
    args = model.example_args_ranged(b=b, m=m, d=d)
    text = aot.lower_entry(model.kde_block_ranged_fn(kind, b=b, m=m, d=d), args)
    assert "HloModule" in text
    assert "f32[8,4]" in text and "f32[64,4]" in text
    assert "s32[8]" in text, "per-row range operands missing"
    assert "f32[8,64]" in text, "block output shape missing"


def test_lowered_block_ranged_entry_computes_correctly():
    """Round-trip the block-ranged module through XLA's own compile+run."""
    b, m, d = 8, 64, 4
    fn = model.kde_block_ranged_fn("gaussian", b=b, m=m, d=d)
    lowered = jax.jit(fn).lower(*model.example_args_ranged(b=b, m=m, d=d))
    r = np.random.default_rng(3)
    q = r.normal(size=(b, d)).astype(np.float32)
    x = r.normal(size=(m, d)).astype(np.float32)
    lo = r.integers(0, m // 2, size=b).astype(np.int32)
    hi = (lo + r.integers(0, m, size=b)).clip(max=m).astype(np.int32)
    got = lowered.compile()(q, x, lo, hi)[0]
    want = ref.kde_block_ranged("gaussian", q, x, jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_lowered_ranged_entry_computes_correctly():
    """Round-trip the ranged module through XLA's own compile+run."""
    b, m, d = 8, 64, 4
    fn = model.kde_sums_ranged_fn("laplacian", b=b, m=m, d=d)
    lowered = jax.jit(fn).lower(*model.example_args_ranged(b=b, m=m, d=d))
    r = np.random.default_rng(1)
    q = r.normal(size=(b, d)).astype(np.float32)
    x = r.normal(size=(m, d)).astype(np.float32)
    lo = r.integers(0, m // 2, size=b).astype(np.int32)
    hi = (lo + r.integers(0, m, size=b)).clip(max=m).astype(np.int32)
    got = lowered.compile()(q, x, lo, hi)[0]
    want = ref.kde_sums_ranged("laplacian", q, x, jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_lowered_entry_computes_correctly():
    """Round-trip the lowered module through XLA's own compile+run."""
    from jax._src.lib import xla_client as xc

    b, m, d = 8, 64, 4
    fn = model.kde_sums_fn("laplacian", b=b, m=m, d=d)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((m, d), jnp.float32),
    )
    r = np.random.default_rng(0)
    q = r.normal(size=(b, d)).astype(np.float32)
    x = r.normal(size=(m, d)).astype(np.float32)
    got = lowered.compile()(q, x)[0]
    want = ref.kde_sums("laplacian", q, x)
    np.testing.assert_allclose(got, want, rtol=2e-5)


def test_manifest_matches_artifacts_if_built():
    """If `make artifacts` has run, the manifest and files must agree."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    with open(man_path) as f:
        man = json.load(f)
    assert man["b"] == model.AOT_B
    assert man["m"] == model.AOT_M
    assert man["d"] == model.AOT_D
    for entry in man["entries"]:
        p = os.path.join(art, f"{entry}.hlo.txt")
        assert os.path.exists(p), p
        with open(p) as f:
            head = f.read(4096)
        assert "HloModule" in head
