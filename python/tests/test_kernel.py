"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis-style sweeps: seeded random generation over shapes, kernels,
scales and degenerate layouts.  (The `hypothesis` package is not available
in this offline image; the sweep loops below are deterministic-seeded
equivalents — every case prints its seed on failure via the assert message.)
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import pairwise, ref

KERNELS = ref.KERNELS
RNG = np.random.default_rng


def _rand(seed, b, m, d, scale=1.0):
    r = RNG(seed)
    q = r.normal(size=(b, d), scale=scale).astype(np.float32)
    x = r.normal(size=(m, d), scale=scale).astype(np.float32)
    return q, x


@pytest.mark.parametrize("kind", KERNELS)
@pytest.mark.parametrize("b,m,d", [(1, 8, 1), (3, 16, 5), (8, 64, 16), (64, 1024, 64)])
def test_kde_sums_matches_ref(kind, b, m, d):
    q, x = _rand(b * 1000 + m + d, b, m, d)
    got = pairwise.make_kde_sums(kind, b, m, d)(q, x)
    want = ref.kde_sums(kind, q, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KERNELS)
@pytest.mark.parametrize("b,m,d", [(1, 8, 1), (3, 16, 5), (8, 64, 16), (64, 1024, 64)])
def test_kernel_block_matches_ref(kind, b, m, d):
    q, x = _rand(b * 2000 + m - d, b, m, d)
    got = pairwise.make_kernel_block(kind, b, m, d)(q, x)
    want = ref.pairwise_kernel(kind, q, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("kind", KERNELS)
def test_sweep_random_shapes(kind):
    """Seeded random shape sweep (hypothesis substitute)."""
    r = RNG(12345)
    for case in range(12):
        b = int(r.integers(1, 17))
        d = int(r.integers(1, 33))
        m = int(r.choice([2, 4, 8, 12, 24, 96, 256]))
        scale = float(r.choice([0.1, 1.0, 3.0]))
        q, x = _rand(case, b, m, d, scale)
        got = pairwise.make_kde_sums(kind, b, m, d)(q, x)
        want = ref.kde_sums(kind, q, x)
        np.testing.assert_allclose(
            got, want, rtol=3e-5, atol=1e-5,
            err_msg=f"case={case} kind={kind} b={b} m={m} d={d} scale={scale}",
        )


@pytest.mark.parametrize("kind", KERNELS)
@pytest.mark.parametrize("b,m,d", [(1, 8, 1), (3, 16, 5), (8, 64, 16), (64, 1024, 64)])
def test_kde_sums_ranged_matches_ref(kind, b, m, d):
    """Range-masked sums: every row attends only to its own [lo, hi)."""
    q, x = _rand(b * 3000 + m + d, b, m, d)
    r = RNG(b + m + d)
    lo = r.integers(0, m, size=b).astype(np.int32)
    hi = (lo + r.integers(0, m, size=b)).clip(max=m).astype(np.int32)
    # Exercise the edges: one full row, one empty row (when b allows).
    lo[0], hi[0] = 0, m
    if b > 1:
        lo[1], hi[1] = m // 2, m // 2
    got = pairwise.make_kde_sums_ranged(kind, b, m, d)(q, x, lo, hi)
    want = ref.kde_sums_ranged(kind, q, x, jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)
    # Full range reduces to the unmasked sums; empty range is exactly zero.
    full = pairwise.make_kde_sums(kind, b, m, d)(q, x)
    np.testing.assert_allclose(got[0], full[0], rtol=2e-5, atol=1e-5)
    if b > 1:
        assert float(got[1]) == 0.0


@pytest.mark.parametrize("kind", KERNELS)
@pytest.mark.parametrize("b,m,d", [(1, 8, 1), (3, 16, 5), (8, 64, 16), (64, 1024, 64)])
def test_kde_block_ranged_matches_ref(kind, b, m, d):
    """Range-masked block: row q's entries live only in [lo, hi)."""
    q, x = _rand(b * 4000 + m + d, b, m, d)
    r = RNG(b * 2 + m + d)
    lo = r.integers(0, m, size=b).astype(np.int32)
    hi = (lo + r.integers(0, m, size=b)).clip(max=m).astype(np.int32)
    # Exercise the edges: one full row, one empty row (when b allows).
    lo[0], hi[0] = 0, m
    if b > 1:
        lo[1], hi[1] = m // 2, m // 2
    got = pairwise.make_kde_block_ranged(kind, b, m, d)(q, x, lo, hi)
    want = ref.kde_block_ranged(kind, q, x, jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)
    # Full range reduces to the unmasked block; empty range is all-zero.
    full = pairwise.make_kernel_block(kind, b, m, d)(q, x)
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(full)[0], rtol=2e-5, atol=1e-6)
    if b > 1:
        assert float(np.abs(np.asarray(got)[1]).max()) == 0.0
    # Entries outside every row's range are exactly 0.0 (the Rust runtime
    # scatters only the in-range slice, but the artifact contract is exact).
    cols = np.arange(m)[None, :]
    outside = (cols < lo[:, None]) | (cols >= hi[:, None])
    assert float(np.abs(np.asarray(got)[outside]).max() if outside.any() else 0.0) == 0.0


def test_kde_block_ranged_rows_match_unmasked_slices():
    """Each masked row equals the plain block over its own sub-slice."""
    kind = "laplacian"
    b, m, d = 4, 256, 8
    q, x = _rand(23, b, m, d)
    lo = np.array([0, 100, 255, 17], dtype=np.int32)
    hi = np.array([1, 156, 256, 200], dtype=np.int32)
    got = np.asarray(pairwise.make_kde_block_ranged(kind, b, m, d)(q, x, lo, hi))
    for row in range(b):
        want = np.asarray(ref.pairwise_kernel(kind, q[row : row + 1], x[lo[row] : hi[row]]))[0]
        np.testing.assert_allclose(got[row, lo[row] : hi[row]], want, rtol=2e-5, atol=1e-6)


def test_kde_sums_ranged_tile_straddling_ranges():
    """Ranges that start/end mid-tile must mask exactly at the boundary."""
    kind = "laplacian"
    b, m, d = 4, 256, 8
    q, x = _rand(19, b, m, d)
    lo = np.array([0, 100, 255, 17], dtype=np.int32)
    hi = np.array([1, 156, 256, 200], dtype=np.int32)
    got = np.asarray(pairwise.make_kde_sums_ranged(kind, b, m, d)(q, x, lo, hi))
    for row in range(b):
        want = float(np.asarray(ref.kde_sums(kind, q[row : row + 1], x[lo[row] : hi[row]]))[0])
        np.testing.assert_allclose(got[row], want, rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KERNELS)
def test_kernel_values_in_unit_interval(kind):
    q, x = _rand(7, 8, 128, 16)
    vals = np.asarray(pairwise.make_kernel_block(kind, 8, 128, 16)(q, x))
    assert vals.min() > 0.0
    assert vals.max() <= 1.0 + 1e-6


@pytest.mark.parametrize("kind", KERNELS)
def test_self_kernel_is_one(kind):
    """k(x, x) = 1 for every kernel in Table 1."""
    _, x = _rand(11, 1, 16, 8)
    vals = np.asarray(pairwise.make_kernel_block(kind, 16, 16, 8)(x, x))
    np.testing.assert_allclose(np.diag(vals), 1.0, rtol=1e-6)


@pytest.mark.parametrize("kind", ["laplacian", "gaussian", "exponential"])
def test_squared_kernel_scaling_law(kind):
    """k(x,y)^2 = k(cx, cy) with c = 2, sqrt(2), 2 — the §5.2 row-norm trick."""
    c = {"laplacian": 2.0, "gaussian": np.sqrt(2.0), "exponential": 2.0}[kind]
    q, x = _rand(13, 4, 32, 8)
    k1 = np.asarray(ref.pairwise_kernel(kind, q, x))
    k2 = np.asarray(ref.pairwise_kernel(kind, c * q, c * x))
    np.testing.assert_allclose(k1 * k1, k2, rtol=1e-4, atol=1e-7)


def test_far_padding_underflows_to_zero():
    """Rust pads data tiles with far points; their kernel mass must be 0.0."""
    q = np.zeros((2, 4), dtype=np.float32)
    far = np.full((8, 4), 1.0e6, dtype=np.float32)
    for kind in ("laplacian", "gaussian", "exponential"):
        sums = np.asarray(ref.kde_sums(kind, q, far))
        assert sums.max() == 0.0, kind
    # rational_quadratic decays polynomially: bounded by 1/(1+4e12) ~ 2.5e-13.
    sums = np.asarray(ref.kde_sums("rational_quadratic", q, far))
    assert sums.max() < 1e-10


def test_tile_accumulation_order_stable():
    """Sums must not depend on the grid tiling (accumulator correctness)."""
    q, x = _rand(17, 4, 256, 8)
    full = pairwise.make_kde_sums("laplacian", 4, 256, 8)(q, x)
    # m=256 tiles as 1x256; m=252 forces an awkward tile; compare prefix.
    part = pairwise.make_kde_sums("laplacian", 4, 192, 8)(q, x[:192])
    want = ref.kde_sums("laplacian", q, x[:192])
    np.testing.assert_allclose(part, want, rtol=2e-5)
    np.testing.assert_allclose(full, ref.kde_sums("laplacian", q, x), rtol=2e-5)
