"""AOT compile path: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The text parser on the Rust side reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Run once per build:  ``make artifacts``  (no-op when inputs unchanged).

Artifacts written:
  artifacts/kde_sums_<kind>.hlo.txt         (B,D),(M,D) -> ((B,),)
  artifacts/kde_sums_ranged_<kind>.hlo.txt  (B,D),(M,D),(B,)i32,(B,)i32 -> ((B,),)
  artifacts/kernel_block_<kind>.hlo.txt     (B,D),(M,D) -> ((B,M),)
  artifacts/kde_block_ranged_<kind>.hlo.txt (B,D),(M,D),(B,)i32,(B,)i32 -> ((B,M),)
  artifacts/manifest.json                   shapes + kernel list for Rust
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import KERNELS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, args=None) -> str:
    lowered = jax.jit(fn).lower(*(args or model.example_args()))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "b": model.AOT_B,
        "m": model.AOT_M,
        "d": model.AOT_D,
        "kernels": list(KERNELS),
        "entries": [],
    }
    for kind in KERNELS:
        for name, builder, entry_args in (
            ("kde_sums", model.kde_sums_fn, model.example_args()),
            ("kde_sums_ranged", model.kde_sums_ranged_fn, model.example_args_ranged()),
            ("kernel_block", model.kernel_block_fn, model.example_args()),
            ("kde_block_ranged", model.kde_block_ranged_fn, model.example_args_ranged()),
        ):
            text = lower_entry(builder(kind), entry_args)
            path = os.path.join(args.out_dir, f"{name}_{kind}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(f"{name}_{kind}")
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
