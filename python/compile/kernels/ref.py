"""Pure-jnp reference oracle for the pairwise kernel computations.

This is the correctness ground truth for the Pallas kernels in
``pairwise.py``: pytest asserts ``allclose`` between the two across shape /
kernel / bandwidth sweeps.  Everything here is deliberately naive —
materialize the full (B, M) pairwise computation with broadcasting.

Kernels (Table 1 of the paper), all with values in (0, 1]:

    laplacian           k(x, y) = exp(-||x - y||_1)
    gaussian            k(x, y) = exp(-||x - y||_2^2)
    exponential         k(x, y) = exp(-||x - y||_2)
    rational_quadratic  k(x, y) = 1 / (1 + ||x - y||_2^2)        (beta = 1)

Bandwidth is folded in by pre-scaling coordinates (x -> x / sigma), which is
exactly what the Rust coordinator does before dispatching to the artifact.
"""

import jax.numpy as jnp

KERNELS = ("laplacian", "gaussian", "exponential", "rational_quadratic")


def pairwise_kernel(kind, queries, data):
    """Full (B, M) kernel block between queries (B, D) and data (M, D)."""
    diff = queries[:, None, :] - data[None, :, :]
    if kind == "laplacian":
        return jnp.exp(-jnp.sum(jnp.abs(diff), axis=-1))
    sq = jnp.sum(diff * diff, axis=-1)
    if kind == "gaussian":
        return jnp.exp(-sq)
    if kind == "exponential":
        return jnp.exp(-jnp.sqrt(jnp.maximum(sq, 0.0)))
    if kind == "rational_quadratic":
        return 1.0 / (1.0 + sq)
    raise ValueError(f"unknown kernel kind: {kind}")


def kde_sums(kind, queries, data):
    """Reference KDE sums: out[b] = sum_m k(queries[b], data[m])."""
    return jnp.sum(pairwise_kernel(kind, queries, data), axis=1)


def kde_sums_ranged(kind, queries, data, lo, hi):
    """Reference range-masked sums: out[b] = sum over m in [lo[b], hi[b])."""
    vals = pairwise_kernel(kind, queries, data)
    rows = jnp.arange(data.shape[0])[None, :]
    mask = (rows >= lo[:, None]) & (rows < hi[:, None])
    return jnp.sum(jnp.where(mask, vals, 0.0), axis=1)


def kde_block_ranged(kind, queries, data, lo, hi):
    """Reference range-masked block: K[b, m] masked to [lo[b], hi[b])."""
    vals = pairwise_kernel(kind, queries, data)
    rows = jnp.arange(data.shape[0])[None, :]
    mask = (rows >= lo[:, None]) & (rows < hi[:, None])
    return jnp.where(mask, vals, 0.0)
