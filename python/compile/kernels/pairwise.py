"""Layer-1 Pallas kernels: tiled pairwise kernel evaluation + reduction.

The compute hot-spot of every KDE query in the paper is "scan the dataset,
accumulate k(x, y)".  We express it as a Pallas kernel that tiles the data
into (TM, D) VMEM blocks, keeps the (B, D) query block resident, computes a
(B, TM) kernel block per grid step and either

  * reduces it into a (B,) accumulator          -> ``make_kde_sums``
  * writes it out as a block of the kernel row  -> ``make_kernel_block``

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO (see DESIGN.md
§Hardware-Adaptation for the TPU tiling rationale; VMEM per grid step is
TB*D + TM*D + TB*TM floats ~ 135 KiB at the AOT shapes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import KERNELS


def _kernel_values(kind, q, d):
    """(B, TM) kernel block from q (B, D) and d (TM, D), inside the kernel."""
    diff = q[:, None, :] - d[None, :, :]
    if kind == "laplacian":
        return jnp.exp(-jnp.sum(jnp.abs(diff), axis=-1))
    sq = jnp.sum(diff * diff, axis=-1)
    if kind == "gaussian":
        return jnp.exp(-sq)
    if kind == "exponential":
        return jnp.exp(-jnp.sqrt(jnp.maximum(sq, 1e-30)))
    if kind == "rational_quadratic":
        return 1.0 / (1.0 + sq)
    raise ValueError(f"unknown kernel kind: {kind}")


def _pick_tile(m):
    """Largest power-of-two tile <= 256 that divides m."""
    for t in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if m % t == 0:
            return t
    return 1


def make_kde_sums(kind, b, m, d, dtype=jnp.float32):
    """Build the tiled KDE-sum function for fixed shapes.

    Returns f(queries (b, d), data (m, d)) -> sums (b,).
    """
    if kind not in KERNELS:
        raise ValueError(f"unknown kernel kind: {kind}")
    tm = _pick_tile(m)
    grid = (m // tm,)

    def kernel(q_ref, d_ref, o_ref):
        j = pl.program_id(0)
        vals = _kernel_values(kind, q_ref[...], d_ref[...])
        part = jnp.sum(vals, axis=1)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += part

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((tm, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), dtype),
        interpret=True,
    )


def make_kde_sums_ranged(kind, b, m, d, dtype=jnp.float32):
    """Build the range-masked KDE-sum function for fixed shapes.

    Returns f(queries (b, d), data (m, d), lo (b,) i32, hi (b,) i32) ->
    sums (b,), where row ``q`` only accumulates data rows in
    ``[lo[q], hi[q])``.  This is the level-fusion entry: the Rust runtime
    packs several tree nodes' query groups into one (b, m) execution, with
    each node's data occupying one contiguous segment of the data input and
    every query row carrying its own segment's row range.  Rows whose range
    is empty (``lo == hi``) contribute exactly 0.0, which also covers the
    B-padding rows.
    """
    if kind not in KERNELS:
        raise ValueError(f"unknown kernel kind: {kind}")
    tm = _pick_tile(m)
    grid = (m // tm,)

    def kernel(q_ref, d_ref, lo_ref, hi_ref, o_ref):
        j = pl.program_id(0)
        vals = _kernel_values(kind, q_ref[...], d_ref[...])
        # Global data-row index of each column of this (b, tm) tile.
        rows = jax.lax.broadcasted_iota(jnp.int32, (q_ref.shape[0], tm), 1) + j * tm
        mask = (rows >= lo_ref[...][:, None]) & (rows < hi_ref[...][:, None])
        part = jnp.sum(jnp.where(mask, vals, 0.0), axis=1)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += part

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((tm, d), lambda j: (j, 0)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), dtype),
        interpret=True,
    )


def make_kde_block_ranged(kind, b, m, d, dtype=jnp.float32):
    """Build the range-masked kernel-block function for fixed shapes.

    Returns f(queries (b, d), data (m, d), lo (b,) i32, hi (b,) i32) ->
    K (b, m), where ``K[q, j] = k(queries[q], data[j])`` for ``j`` in
    ``[lo[q], hi[q])`` and exactly 0.0 outside.  This is the LRA
    row-construction entry: the Rust runtime chunks the sampled rows into
    (b, m) executions, each row carrying its own data range, and gathers
    the masked rows into a ragged buffer.  Rows whose range is empty
    (``lo == hi``) — including the B-padding rows — contribute all-zero
    output that the runtime never reads.
    """
    if kind not in KERNELS:
        raise ValueError(f"unknown kernel kind: {kind}")
    tm = _pick_tile(m)
    grid = (m // tm,)

    def kernel(q_ref, d_ref, lo_ref, hi_ref, o_ref):
        j = pl.program_id(0)
        vals = _kernel_values(kind, q_ref[...], d_ref[...])
        # Global data-row index of each column of this (b, tm) tile.
        rows = jax.lax.broadcasted_iota(jnp.int32, (q_ref.shape[0], tm), 1) + j * tm
        mask = (rows >= lo_ref[...][:, None]) & (rows < hi_ref[...][:, None])
        o_ref[...] = jnp.where(mask, vals, 0.0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((tm, d), lambda j: (j, 0)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, tm), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), dtype),
        interpret=True,
    )


def make_kernel_block(kind, b, m, d, dtype=jnp.float32):
    """Build the tiled kernel-block function for fixed shapes.

    Returns f(queries (b, d), data (m, d)) -> K (b, m), the dense block of
    kernel values (used for explicit row construction in LRA and for exact
    neighbor weights).
    """
    if kind not in KERNELS:
        raise ValueError(f"unknown kernel kind: {kind}")
    tm = _pick_tile(m)
    grid = (m // tm,)

    def kernel(q_ref, d_ref, o_ref):
        o_ref[...] = _kernel_values(kind, q_ref[...], d_ref[...])

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((tm, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, tm), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), dtype),
        interpret=True,
    )


@functools.lru_cache(maxsize=None)
def cached_kde_sums(kind, b, m, d):
    return make_kde_sums(kind, b, m, d)


@functools.lru_cache(maxsize=None)
def cached_kernel_block(kind, b, m, d):
    return make_kernel_block(kind, b, m, d)
