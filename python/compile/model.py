"""Layer-2 JAX compute graphs for the KDE query engine.

These are the functions that get AOT-lowered (once, at build time) to HLO
text and executed from the Rust request path via PJRT.  Each graph wraps the
Layer-1 Pallas kernel from ``kernels.pairwise`` so that the kernel lowers
into the same HLO module.

Three entry points per kernel type:

  * ``kde_sums``        (B, D), (M, D) -> (B,)     batched KDE queries
  * ``kde_sums_ranged`` (B, D), (M, D), (B,) i32, (B,) i32 -> (B,)
    range-masked sums: row q only accumulates data rows in [lo[q], hi[q]).
    The level-fusion entry — the Rust runtime packs several tree nodes'
    query groups into one execution, one data segment per node.
  * ``kernel_block``    (B, D), (M, D) -> (B, M)   explicit kernel rows
  * ``kde_block_ranged`` (B, D), (M, D), (B,) i32, (B,) i32 -> (B, M)
    range-masked kernel rows (0.0 outside [lo[q], hi[q])) — the LRA
    row-construction entry, executed in planner-sized chunks.

AOT shapes (must match ``rust/src/runtime``):  B = 64, M = 1024, D = 64.
The Rust side pads queries/data to these shapes; padding *data* rows are
placed at a far coordinate (1e6 on every axis) so their kernel mass
underflows to exactly 0.0 in f32 and never perturbs the sums.
"""

import jax

from .kernels import pairwise

# The fixed AOT interface shapes.  Keep in sync with rust/src/runtime/shapes.
AOT_B = 64
AOT_M = 1024
AOT_D = 64


def kde_sums_fn(kind, b=AOT_B, m=AOT_M, d=AOT_D):
    """Batched KDE sums graph for a fixed kernel kind and shapes."""
    inner = pairwise.make_kde_sums(kind, b, m, d)

    def f(queries, data):
        return (inner(queries, data),)

    return f


def kde_sums_ranged_fn(kind, b=AOT_B, m=AOT_M, d=AOT_D):
    """Range-masked KDE sums graph (the level-fusion entry)."""
    inner = pairwise.make_kde_sums_ranged(kind, b, m, d)

    def f(queries, data, lo, hi):
        return (inner(queries, data, lo, hi),)

    return f


def kernel_block_fn(kind, b=AOT_B, m=AOT_M, d=AOT_D):
    """Dense kernel block graph for a fixed kernel kind and shapes."""
    inner = pairwise.make_kernel_block(kind, b, m, d)

    def f(queries, data):
        return (inner(queries, data),)

    return f


def kde_block_ranged_fn(kind, b=AOT_B, m=AOT_M, d=AOT_D):
    """Range-masked kernel block graph (the LRA row-construction entry)."""
    inner = pairwise.make_kde_block_ranged(kind, b, m, d)

    def f(queries, data, lo, hi):
        return (inner(queries, data, lo, hi),)

    return f


def example_args(b=AOT_B, m=AOT_M, d=AOT_D):
    """ShapeDtypeStructs for lowering the (queries, data) entries."""
    import jax.numpy as jnp

    return (
        jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((m, d), jnp.float32),
    )


def example_args_ranged(b=AOT_B, m=AOT_M, d=AOT_D):
    """ShapeDtypeStructs for lowering the ranged entries."""
    import jax.numpy as jnp

    return example_args(b, m, d) + (
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
