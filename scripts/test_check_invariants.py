#!/usr/bin/env python3
"""Synthetic-tree self-tests for scripts/check_invariants.py. Run
directly:

    python3 scripts/test_check_invariants.py

Stdlib only, no test framework — each case writes a tiny rust/src tree
into a temp dir, seeds (or doesn't seed) one violation, and asserts on
check_invariants.run()'s exit code and report. The final case runs the
checker against the real repository tree, which must be clean.
"""

import os
import sys
import tempfile
from contextlib import redirect_stderr, redirect_stdout
from io import StringIO

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import check_invariants  # noqa: E402

CLEAN_BACKEND = """
pub trait KernelBackend: Send + Sync {
    fn sums(&self, kernel: Kernel, q: &[f32]) -> Vec<f64>;
    fn try_sums(&self, kernel: Kernel, q: &[f32]) -> BackendResult<Vec<f64>>;
    fn block(&self, kernel: Kernel, q: &[f32]) -> Vec<f32>;
    fn try_block(&self, kernel: Kernel, q: &[f32]) -> BackendResult<Vec<f32>>;
    fn name(&self) -> &'static str;
    fn calls(&self) -> u64;
}
"""

MISSING_TWIN_BACKEND = """
pub trait KernelBackend: Send + Sync {
    fn sums(&self, kernel: Kernel, q: &[f32]) -> Vec<f64>;
    fn try_sums(&self, kernel: Kernel, q: &[f32]) -> BackendResult<Vec<f64>>;
    fn block(&self, kernel: Kernel, q: &[f32]) -> Vec<f32>;
    fn name(&self) -> &'static str;
}
"""

MULTILINE_SIG_BACKEND = """
pub trait KernelBackend: Send + Sync {
    fn sums_ranged(
        &self,
        kernel: Kernel,
        ranges: &[(u32, u32)],
    ) -> Vec<f64> {
        Vec::new()
    }
    fn name(&self) -> &'static str;
}
"""


def write_tree(root, files):
    """files: {relpath under rust/src: contents}; returns the repo root."""
    for rel, body in files.items():
        path = os.path.join(root, "rust", "src", rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            f.write(body)
    return root


def run_checker(files):
    """Build the tree, run the checker, return (exit_code, output)."""
    with tempfile.TemporaryDirectory() as td:
        write_tree(td, files)
        out = StringIO()
        with redirect_stdout(out), redirect_stderr(out):
            code = check_invariants.run(td)
        return code, out.getvalue()


def base_tree(**extra):
    files = {"runtime/backend.rs": CLEAN_BACKEND}
    files.update(extra)
    return files


def expect(code, output, want_code, want_id=None, case=""):
    assert code == want_code, f"{case}: exit {code} != {want_code}\n{output}"
    if want_id is not None:
        assert f"[{want_id}]" in output, f"{case}: no {want_id} in:\n{output}"
    print(f"PASS {case}")


def test_clean_tree_passes():
    code, out = run_checker(base_tree())
    expect(code, out, 0, case="clean_tree_passes")


def test_missing_try_twin_flagged():
    code, out = run_checker({"runtime/backend.rs": MISSING_TWIN_BACKEND})
    expect(code, out, 1, "I1", "missing_try_twin_flagged")
    assert "try_block" in out, out


def test_multiline_signature_twin_flagged():
    # The `kernel: Kernel` parameter sits on its own line; the checker
    # must still join the signature and demand a twin.
    code, out = run_checker({"runtime/backend.rs": MULTILINE_SIG_BACKEND})
    expect(code, out, 1, "I1", "multiline_signature_twin_flagged")
    assert "try_sums_ranged" in out, out


def test_metadata_entries_need_no_twin():
    # `name`/`calls` take no `kernel: Kernel`; the clean trait passes
    # even though they have no try_ siblings (asserted by the clean case,
    # re-asserted here against a trait with ONLY metadata entries).
    code, out = run_checker({
        "runtime/backend.rs":
            "pub trait KernelBackend {\n"
            "    fn name(&self) -> &'static str;\n"
            "    fn kernel_evals(&self) -> u64;\n"
            "}\n"
    })
    expect(code, out, 0, case="metadata_entries_need_no_twin")


def test_spawn_outside_allowlist_flagged():
    code, out = run_checker(base_tree(**{
        "kde/rogue.rs": "pub fn go() {\n    std::thread::spawn(|| {});\n}\n"
    }))
    expect(code, out, 1, "I2", "spawn_outside_allowlist_flagged")


def test_spawn_in_sanctioned_module_ok():
    code, out = run_checker(base_tree(**{
        "runtime/pool.rs": "pub fn go() {\n    std::thread::spawn(|| {});\n}\n",
        "coordinator/batcher.rs":
            "pub fn go() {\n    std::thread::scope(|s| {});\n}\n",
    }))
    expect(code, out, 0, case="spawn_in_sanctioned_module_ok")


def test_spawn_in_test_module_ok():
    code, out = run_checker(base_tree(**{
        "apps/thing.rs":
            "pub fn go() {}\n"
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    #[test]\n"
            "    fn t() {\n"
            "        std::thread::spawn(|| {}).join().unwrap();\n"
            "    }\n"
            "}\n",
    }))
    expect(code, out, 0, case="spawn_in_test_module_ok")


def test_std_sync_import_in_rebased_module_flagged():
    code, out = run_checker(base_tree(**{
        "server/store.rs": "use std::sync::{Arc, Mutex};\npub fn f() {}\n",
    }))
    expect(code, out, 1, "I3", "std_sync_import_in_rebased_module_flagged")


def test_std_sync_import_elsewhere_ok():
    # Non-rebased modules may use std::sync directly (they are not part
    # of the loom model).
    code, out = run_checker(base_tree(**{
        "apps/thing.rs": "use std::sync::Mutex;\npub fn f() {}\n",
        "coordinator/batcher.rs": "use std::sync::OnceLock;\npub fn f() {}\n",
    }))
    expect(code, out, 0, case="std_sync_import_elsewhere_ok")


def test_unwrap_in_gated_dir_flagged():
    code, out = run_checker(base_tree(**{
        "sampling/thing.rs":
            "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n",
    }))
    expect(code, out, 1, "I4", "unwrap_in_gated_dir_flagged")


def test_expect_in_gated_dir_flagged():
    code, out = run_checker(base_tree(**{
        "kde/thing.rs":
            'pub fn f(v: Option<u32>) -> u32 {\n    v.expect("always")\n}\n',
    }))
    expect(code, out, 1, "I4", "expect_in_gated_dir_flagged")


def test_unwrap_variants_and_tests_ok():
    # unwrap_or* / expect_err / doc comments / loom+test modules are all
    # exempt.
    code, out = run_checker(base_tree(**{
        "kde/thing.rs":
            "//! module docs with `v.unwrap()` in them\n"
            "pub fn f(v: Option<u32>) -> u32 {\n"
            "    // an inline comment saying .unwrap() is fine\n"
            "    v.unwrap_or_else(|| 3).max(v.unwrap_or(2))\n"
            "}\n"
            "pub fn g(r: Result<u32, u32>) -> u32 {\n"
            "    r.expect_err(\"want err\")\n"
            "}\n"
            "#[cfg(test)]\n"
            "#[allow(clippy::unwrap_used, clippy::expect_used)]\n"
            "mod tests {\n"
            "    #[test]\n"
            "    fn t() {\n"
            "        Some(1).unwrap();\n"
            "    }\n"
            "}\n"
            "#[cfg(all(loom, test))]\n"
            "mod loom_tests {\n"
            "    #[test]\n"
            "    fn l() {\n"
            "        Some(1).unwrap();\n"
            "    }\n"
            "}\n",
    }))
    expect(code, out, 0, case="unwrap_variants_and_tests_ok")


def test_unwrap_in_gated_file_flagged():
    # apps/ as a whole is not gated, but the dynamic-mutation files
    # (GATED_FILES) carry the same unwrap-free bar as the gated dirs.
    code, out = run_checker(base_tree(**{
        "apps/resparsify.rs":
            "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n",
        "apps/other.rs":
            "pub fn g(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n",
    }))
    expect(code, out, 1, "I4", "unwrap_in_gated_file_flagged")
    assert "resparsify.rs" in out, out
    assert "other.rs" not in out, out


def test_unwrap_outside_gated_dirs_ok():
    code, out = run_checker(base_tree(**{
        "util/thing.rs":
            "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n",
    }))
    expect(code, out, 0, case="unwrap_outside_gated_dirs_ok")


def test_real_repo_is_clean():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = StringIO()
    with redirect_stdout(out), redirect_stderr(out):
        code = check_invariants.run(repo)
    expect(code, out.getvalue(), 0, case="real_repo_is_clean")


def main():
    cases = [v for k, v in sorted(globals().items())
             if k.startswith("test_") and callable(v)]
    for case in cases:
        case()
    print(f"all {len(cases)} check_invariants self-tests passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
