#!/usr/bin/env python3
"""Repo-invariant lint pass over rust/src (CI-blocking; see
docs/ARCHITECTURE.md §Verification matrix).

Four invariants that rustc/clippy cannot express, each enforced by
parsing the source tree (stdlib only, no toolchain needed):

I1  try-twins      Every infallible `KernelBackend` dispatch entry (a
                   trait method taking `kernel: Kernel`) has a fallible
                   `try_*` twin in the same trait — the failure model's
                   contract (ARCHITECTURE.md §Failure model).
I2  spawn-sites    `thread::spawn` / `thread::scope` / `thread::Builder`
                   appear only in the sanctioned executor modules; every
                   other module must go through `WorkerPool` or the
                   batcher session. Test modules are exempt.
I3  sync-facade    Modules rebased onto `runtime::sync` (the loom facade)
                   must not import `std::sync::{Mutex, Condvar}` or
                   `std::sync::mpsc` directly — a direct import silently
                   drops the primitive out of the loom model.
I4  no-unwrap      No new `.unwrap()` / `.expect(` in non-test code under
                   the gated directories. This backstops the per-module
                   clippy deny gates at a layer that also catches a
                   module whose gate line was deleted.

Usage:
    python3 scripts/check_invariants.py [--root DIR]

Exit code 0 when every invariant holds; 1 with one line per violation
(`file:line: [ID] message`) otherwise.
"""

import argparse
import os
import re
import sys

# I2: modules allowed to spawn OS threads directly. Everything else uses
# the pool (runtime/pool.rs) or the batcher's sanctioned session/scope.
SPAWN_ALLOWLIST = {
    "runtime/pool.rs",       # the executor itself
    "runtime/sync.rs",       # the facade's spawn_named shim
    "runtime/tiled.rs",      # legacy scoped fallback (run_scoped_threads)
    "coordinator/batcher.rs",  # double-buffered scope + session worker
    "server/mod.rs",         # the server router thread
    "server/registry.rs",    # scoped per-dataset build fan-out
}

# I3: modules rebased onto the runtime::sync facade (ARCHITECTURE.md
# §Verification matrix). runtime/sync.rs itself is the one place the
# std primitives may be named.
REBASED = {
    "runtime/pool.rs",
    "coordinator/batcher.rs",
    "server/store.rs",
    "server/mod.rs",
}

# I4: directories whose non-test code must stay unwrap/expect-free.
GATED_DIRS = ("runtime/", "coordinator/", "server/", "kde/", "sampling/")
# I4: individual files outside the gated dirs that carry the dynamic
# mutation path (tombstone datasets, the maintained sparsifier) and must
# meet the same bar.
GATED_FILES = ("apps/resparsify.rs", "kernel/dataset.rs")

SPAWN_RE = re.compile(r"\bthread::(spawn|scope)\s*\(|\bthread::Builder\b")
UNWRAP_RE = re.compile(r"\.unwrap\(\)|\.expect\(")
SYNC_IMPORT_RE = re.compile(r"^\s*(?:pub\s+)?use\s+std::sync\b")
SYNC_PRIMS_RE = re.compile(r"\b(Mutex|Condvar|mpsc|atomic)\b")
TEST_CFG_RE = re.compile(r"#\[cfg\((?:all\()?[^)]*\btest\b")
MOD_RE = re.compile(r"^\s*(?:pub\s+)?mod\s+\w+\s*\{")


COMMENT_RE = re.compile(r"(?<!:)//")


def strip_comments(line):
    """Drop `//`-to-EOL (incl. doc comments), leaving `://` (URLs inside
    string literals) alone. Good enough for lint patterns: none of them
    can occur inside a string literal in this codebase without also
    occurring as real code."""
    m = COMMENT_RE.search(line)
    return line if m is None else line[:m.start()]


def test_regions(lines):
    """Line-index set covered by `#[cfg(test)] mod ... { }` (or any cfg
    containing `test`, e.g. `#[cfg(all(loom, test))]`) — tracked by brace
    balance from the `mod` line."""
    covered = set()
    i = 0
    n = len(lines)
    while i < n:
        if TEST_CFG_RE.search(strip_comments(lines[i])):
            # Attributes (allow, cfg_attr, ...) may sit between the cfg
            # and the mod line; look a few lines ahead.
            j = i + 1
            while j < n and j <= i + 4 and not MOD_RE.search(lines[j]):
                if not lines[j].lstrip().startswith("#["):
                    break
                j += 1
            if j < n and MOD_RE.search(lines[j]):
                depth = 0
                k = j
                while k < n:
                    code = strip_comments(lines[k])
                    depth += code.count("{") - code.count("}")
                    covered.add(k)
                    if depth <= 0 and k > j:
                        break
                    if depth <= 0 and k == j and code.count("{") > 0 \
                            and code.count("}") >= code.count("{"):
                        break
                    k += 1
                i = k
        i += 1
    return covered


def check_try_twins(src_root, violations):
    backend = os.path.join(src_root, "runtime", "backend.rs")
    if not os.path.exists(backend):
        violations.append((backend, 0, "I1", "runtime/backend.rs missing"))
        return
    with open(backend, encoding="utf-8") as f:
        lines = f.read().split("\n")
    # Find the `pub trait KernelBackend` block by brace balance.
    start = None
    for i, l in enumerate(lines):
        if re.search(r"\btrait\s+KernelBackend\b", strip_comments(l)):
            start = i
            break
    if start is None:
        violations.append((backend, 0, "I1", "trait KernelBackend not found"))
        return
    depth = 0
    body = []
    for i in range(start, len(lines)):
        code = strip_comments(lines[i])
        depth += code.count("{") - code.count("}")
        body.append((i + 1, code))
        if depth <= 0 and i > start:
            break
    # Collect method names + full signatures (joined until `)` or `{`).
    names = set()
    dispatch = []  # (line, name) for entries taking `kernel: Kernel`
    for idx, (ln, code) in enumerate(body):
        m = re.search(r"\bfn\s+(\w+)\s*\(", code)
        if not m:
            continue
        name = m.group(1)
        names.add(name)
        sig = code
        j = idx
        while "(" in sig and sig.count("(") > sig.count(")") and j + 1 < len(body):
            j += 1
            sig += " " + body[j][1]
        if re.search(r"\bkernel\s*:\s*Kernel\b", sig):
            dispatch.append((ln, name))
    for ln, name in dispatch:
        if name.startswith("try_"):
            continue
        if f"try_{name}" not in names:
            violations.append((
                backend, ln, "I1",
                f"KernelBackend::{name} takes `kernel: Kernel` but has no "
                f"`try_{name}` twin (failure-model contract)",
            ))


def check_file(path, rel, violations):
    with open(path, encoding="utf-8") as f:
        lines = f.read().split("\n")
    tests = test_regions(lines)
    in_gated = any(rel.startswith(d) for d in GATED_DIRS) \
        or rel in GATED_FILES
    for i, raw in enumerate(lines):
        if i in tests:
            continue
        code = strip_comments(raw)
        if not code.strip():
            continue
        if rel not in SPAWN_ALLOWLIST and SPAWN_RE.search(code):
            violations.append((
                path, i + 1, "I2",
                "direct thread spawn/scope outside the sanctioned executor "
                "modules — route work through WorkerPool or the batcher "
                "session",
            ))
        if rel in REBASED and rel != "runtime/sync.rs" \
                and SYNC_IMPORT_RE.search(code) and SYNC_PRIMS_RE.search(code):
            violations.append((
                path, i + 1, "I3",
                "rebased module imports std::sync primitives directly — "
                "use crate::runtime::sync (the loom facade) instead",
            ))
        if in_gated and UNWRAP_RE.search(code):
            violations.append((
                path, i + 1, "I4",
                "unwrap()/expect() in non-test code — return a typed error "
                "or use unwrap_or_else(PoisonError::into_inner) / an "
                "unreachable!() match with a written invariant",
            ))


def run(root):
    src_root = os.path.join(root, "rust", "src")
    violations = []
    if not os.path.isdir(src_root):
        print(f"check_invariants: {src_root} not found", file=sys.stderr)
        return 2
    check_try_twins(src_root, violations)
    for dirpath, _, files in sorted(os.walk(src_root)):
        for fname in sorted(files):
            if not fname.endswith(".rs"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root).replace(os.sep, "/")
            check_file(path, rel, violations)
    for path, line, ident, msg in violations:
        print(f"{path}:{line}: [{ident}] {msg}")
    if violations:
        print(f"check_invariants: {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    print("check_invariants: all invariants hold")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    default_root = os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    ap.add_argument("--root", default=default_root,
                    help="repository root (default: this script's parent)")
    args = ap.parse_args(argv)
    return run(args.root)


if __name__ == "__main__":
    sys.exit(main())
