#!/usr/bin/env python3
"""Synthetic-JSON self-tests for scripts/compare_bench.py (both the
backend-series mode and the --serving mode). Run directly:

    python3 scripts/test_compare_bench.py

Stdlib only, no test framework — each case builds baseline/fresh docs in
a temp dir and asserts on compare_bench.main()'s exit code.
"""

import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import compare_bench  # noqa: E402


def backend_doc(pairs_per_sec=1.0e9, simd_ratio=1.5, isa="avx2",
                baseline="measured", pooled_speedup=1.4,
                drop_series=(), drop_fusion=()):
    """A complete, passing BENCH_backend.json document."""
    results = []
    for kernel in compare_bench.KERNELS:
        for backend in compare_bench.BACKENDS:
            if (kernel, backend) in drop_series:
                continue
            pps = pairs_per_sec
            if backend == "tiled_1t":
                pps = pairs_per_sec * simd_ratio
            results.append({"kernel": kernel, "backend": backend,
                            "isa": isa, "mean_ns": 1.0e6,
                            "pairs_per_sec": pps})
    doc = {
        "bench": "backend_sums", "n": 4096, "d": 64,
        "isa_detected": isa, "baseline": baseline,
        "fusion": {"n": 4096, "t": 64, "d": 16, "log2_n": 12,
                   "dispatches_fused": 40, "dispatches_unfused": 4000,
                   "round_us_fused": 10, "round_us_unfused": 100},
        "walk_fusion": {"n": 4096, "t": 8, "walkers": 32, "log2_n": 12,
                        "dispatches_batched": 96,
                        "dispatches_sequential": 2000,
                        "walk_us_batched": 10, "walk_us_sequential": 100},
        "edge_fusion": {"n": 4096, "pool": 64, "reps": 8, "log2_n": 12,
                        "dispatches_batched": 24,
                        "dispatches_sequential": 600,
                        "est_us_batched": 10, "est_us_sequential": 100},
        "block_fusion": {"n": 4096, "s": 160, "d": 16,
                         "dispatches_chunked": 3,
                         "dispatches_monolithic": 1,
                         "peak_rows_chunked": 64,
                         "peak_rows_monolithic": 160,
                         "block_us_chunked": 10, "block_us_monolithic": 10},
        "executor": {"n": 4096, "b": 64, "d": 16, "threads": 4,
                     "dispatches": 256,
                     "dispatch_us_pooled": int(100 / pooled_speedup),
                     "dispatch_us_scoped": 100,
                     "pooled_speedup": pooled_speedup,
                     "pool_busy_max": 4, "pool_queued_max": 7,
                     "pool_steals": 12, "pool_submitted": 1024,
                     "pool_inline_runs": 0},
        "results": results,
    }
    for key in drop_fusion:
        del doc[key]
    return doc


def serving_doc(p99_us=900.0, throughput_qps=40000.0, dpq=0.05,
                solo_dpq=1.0, isa="avx2", baseline="measured",
                serving_present=True):
    """A complete, passing BENCH_serving.json document."""
    doc = {"bench": "serving", "baseline": baseline, "isa_detected": isa}
    if serving_present:
        doc["serving"] = {
            "n": 4096, "d": 16, "datasets": 2, "clients": 8,
            "requests": 768,
            "p50_us": p99_us / 3.0, "p99_us": p99_us,
            "throughput_qps": throughput_qps,
            "dispatches": int(768 * dpq), "queries": 768,
            "dispatches_per_query": dpq,
            "mean_flush_occupancy": 1.0 / dpq if dpq else 0.0,
            "solo_p50_us": 80.0, "solo_p99_us": 200.0,
            "solo_throughput_qps": 9000.0,
            "solo_dispatches_per_query": solo_dpq,
            "coalescing_ratio": solo_dpq / dpq if dpq else 0.0,
        }
    else:
        doc["serving"] = None
    return doc


def scale_doc(dpq_100k=25.0, dpq_1m=32.0, batch_ns=2.0e6, isa="avx2",
              baseline="measured", million=True, series_present=True):
    """A complete, passing BENCH_scale.json document."""
    doc = {"bench": "scale", "baseline": baseline, "isa_detected": isa}
    if not series_present:
        doc["scale"] = None
        return doc
    series = [{"n": 100_000, "log2_n": 16.610, "walkers": 64,
               "dispatches": int(64 * dpq_100k),
               "dispatches_per_query": dpq_100k,
               "build_ms": 900.0, "batch_mean_ns": batch_ns}]
    if million:
        series.append({"n": 1_000_000, "log2_n": 19.932, "walkers": 64,
                       "dispatches": int(64 * dpq_1m),
                       "dispatches_per_query": dpq_1m,
                       "build_ms": 11000.0, "batch_mean_ns": batch_ns * 1.3})
    doc["scale"] = {"d": 4, "leaf_cutoff": 16, "eps": 0.5, "tau": 0.2,
                    "dispatch_factor_budget": 4.0, "series": series}
    return doc


def run(baseline, fresh, serving=False, scale=False, env=None):
    """Write the two docs to disk and invoke compare_bench.main()."""
    saved = {}
    for k, v in (env or {}).items():
        saved[k] = os.environ.get(k)
        os.environ[k] = v
    try:
        with tempfile.TemporaryDirectory() as td:
            bp = os.path.join(td, "baseline.json")
            fp = os.path.join(td, "fresh.json")
            with open(bp, "w") as f:
                json.dump(baseline, f)
            with open(fp, "w") as f:
                json.dump(fresh, f)
            argv = ["compare_bench.py"]
            if serving:
                argv.append("--serving")
            if scale:
                argv.append("--scale")
            argv += [bp, fp]
            return compare_bench.main(argv)
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


CASES = []


def case(name):
    def wrap(fn):
        CASES.append((name, fn))
        return fn
    return wrap


# ---------------------------------------------------------------- backend

@case("backend: identical measured runs pass")
def _():
    assert run(backend_doc(), backend_doc()) == 0


@case("backend: bootstrap baseline skips per-series comparison")
def _():
    bootstrap = {"bench": "backend_sums", "baseline": "bootstrap",
                 "isa_detected": "unmeasured", "results": []}
    assert run(bootstrap, backend_doc()) == 0


@case("backend: >15% per-series throughput regression fails")
def _():
    assert run(backend_doc(pairs_per_sec=1.0e9),
               backend_doc(pairs_per_sec=0.8e9)) == 1


@case("backend: missing series in the fresh run fails")
def _():
    fresh = backend_doc(drop_series={("gaussian", "tiled_mt")})
    assert run(backend_doc(), fresh) == 1


@case("backend: missing fusion object fails")
def _():
    assert run(backend_doc(), backend_doc(drop_fusion=("fusion",))) == 1


@case("backend: SIMD below the speedup floor fails")
def _():
    assert run(backend_doc(), backend_doc(simd_ratio=1.05)) == 1


@case("backend: ISA mismatch downgrades baseline to bootstrap")
def _():
    # 20% slower than baseline, but measured on a different ISA: the
    # per-series comparison is skipped, within-run gates still pass.
    assert run(backend_doc(isa="avx2"),
               backend_doc(isa="neon", pairs_per_sec=0.8e9)) == 0


@case("backend: missing executor object fails")
def _():
    assert run(backend_doc(), backend_doc(drop_fusion=("executor",))) == 1


@case("backend: pool losing to scoped spawns fails the default floor")
def _():
    # pooled_speedup < 1.0: the persistent pool is slower than spawning
    # threads per dispatch — a within-run gate, so it fails even on a
    # bootstrap baseline.
    bootstrap = {"bench": "backend_sums", "baseline": "bootstrap",
                 "isa_detected": "unmeasured", "results": []}
    assert run(bootstrap, backend_doc(pooled_speedup=0.85)) == 1


@case("backend: executor floor is tunable via EXECUTOR_POOL_FLOOR")
def _():
    doc = backend_doc(pooled_speedup=1.4)
    assert run(backend_doc(), doc,
               env={"EXECUTOR_POOL_FLOOR": "2.0"}) == 1
    assert run(backend_doc(), doc,
               env={"EXECUTOR_POOL_FLOOR": "1.2"}) == 0


# ---------------------------------------------------------------- serving

@case("serving: identical measured runs pass")
def _():
    assert run(serving_doc(), serving_doc(), serving=True) == 0


@case("serving: bootstrap baseline skips the latency comparison")
def _():
    bootstrap = {"bench": "serving", "baseline": "bootstrap",
                 "isa_detected": "unmeasured", "serving": None}
    assert run(bootstrap, serving_doc(), serving=True) == 0


@case("serving: missing serving object in the fresh run fails")
def _():
    assert run(serving_doc(), serving_doc(serving_present=False),
               serving=True) == 1


@case("serving: coalescing floor violation fails even on bootstrap")
def _():
    bootstrap = {"bench": "serving", "baseline": "bootstrap",
                 "isa_detected": "unmeasured", "serving": None}
    # dispatches/query only 1.5x better than solo: below the 2x floor.
    assert run(bootstrap, serving_doc(dpq=0.67, solo_dpq=1.0),
               serving=True) == 1


@case("serving: >15% p99 latency regression fails")
def _():
    assert run(serving_doc(p99_us=900.0),
               serving_doc(p99_us=1100.0), serving=True) == 1


@case("serving: >15% throughput regression fails")
def _():
    assert run(serving_doc(throughput_qps=40000.0),
               serving_doc(throughput_qps=30000.0), serving=True) == 1


@case("serving: regressions inside tolerance pass")
def _():
    assert run(serving_doc(p99_us=900.0, throughput_qps=40000.0),
               serving_doc(p99_us=990.0, throughput_qps=37000.0),
               serving=True) == 0


@case("serving: ISA mismatch skips the latency comparison")
def _():
    assert run(serving_doc(isa="avx2", p99_us=900.0),
               serving_doc(isa="neon", p99_us=5000.0), serving=True) == 0


@case("serving: floor is tunable via SERVING_COALESCE_FLOOR")
def _():
    bootstrap = {"bench": "serving", "baseline": "bootstrap",
                 "isa_detected": "unmeasured", "serving": None}
    doc = serving_doc(dpq=0.25, solo_dpq=1.0)  # 4x ratio
    assert run(bootstrap, doc, serving=True,
               env={"SERVING_COALESCE_FLOOR": "8.0"}) == 1
    assert run(bootstrap, doc, serving=True,
               env={"SERVING_COALESCE_FLOOR": "3.0"}) == 0


# ------------------------------------------------------------------ scale

SCALE_BOOTSTRAP = {"bench": "scale", "baseline": "bootstrap",
                   "isa_detected": "unmeasured", "scale": None}


@case("scale: identical measured runs pass")
def _():
    assert run(scale_doc(), scale_doc(), scale=True) == 0


@case("scale: bootstrap baseline skips the per-n comparison")
def _():
    assert run(SCALE_BOOTSTRAP, scale_doc(), scale=True) == 0


@case("scale: missing series in the fresh run fails")
def _():
    assert run(scale_doc(), scale_doc(series_present=False), scale=True) == 1


@case("scale: dispatches/query above 4 x log2(n) fails even on bootstrap")
def _():
    # 80 > 4 * log2(1e5) = 66.4 — a within-run gate.
    assert run(SCALE_BOOTSTRAP, scale_doc(dpq_100k=80.0, million=False),
               scale=True) == 1


@case("scale: log-like growth between n points passes")
def _():
    # 25 -> 32 is x1.28, within the x1.80 log budget.
    assert run(SCALE_BOOTSTRAP, scale_doc(dpq_100k=25.0, dpq_1m=32.0),
               scale=True) == 0


@case("scale: super-logarithmic growth fails even on bootstrap")
def _():
    # 25 -> 60 is x2.4, past log2 growth (x1.2) times the 1.5 slack.
    assert run(SCALE_BOOTSTRAP, scale_doc(dpq_100k=25.0, dpq_1m=60.0),
               scale=True) == 1


@case("scale: single-point series skips the growth gate")
def _():
    assert run(SCALE_BOOTSTRAP, scale_doc(million=False), scale=True) == 0


@case("scale: dispatch drift beyond 1.25x of measured baseline fails")
def _():
    assert run(scale_doc(dpq_100k=25.0, dpq_1m=32.0),
               scale_doc(dpq_100k=33.0, dpq_1m=42.0), scale=True) == 1


@case("scale: >15% batched-sample latency regression fails")
def _():
    assert run(scale_doc(batch_ns=2.0e6),
               scale_doc(batch_ns=2.4e6), scale=True) == 1


@case("scale: ISA mismatch skips the per-n comparison")
def _():
    assert run(scale_doc(isa="avx2", batch_ns=2.0e6),
               scale_doc(isa="neon", batch_ns=9.0e6), scale=True) == 0


@case("scale: factor budget is tunable via SCALE_DISPATCH_FACTOR")
def _():
    doc = scale_doc(dpq_100k=25.0, million=False)
    assert run(SCALE_BOOTSTRAP, doc, scale=True,
               env={"SCALE_DISPATCH_FACTOR": "1.0"}) == 1
    assert run(SCALE_BOOTSTRAP, doc, scale=True,
               env={"SCALE_DISPATCH_FACTOR": "2.0"}) == 0


def main():
    failures = 0
    for name, fn in CASES:
        try:
            fn()
            print(f"PASS {name}")
        except AssertionError:
            failures += 1
            print(f"FAIL {name}")
    if failures:
        print(f"\n{failures}/{len(CASES)} self-test case(s) failed")
        return 1
    print(f"\nall {len(CASES)} compare_bench self-test cases passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
