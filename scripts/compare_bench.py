#!/usr/bin/env python3
"""Bench-regression gate for the kernel-backend series (CI).

Compares a fresh `BENCH_backend.json` (written by `cargo bench --bench
bench_kde`) against the committed baseline and fails on

  * a missing (kernel, backend) series — the bench stopped measuring
    something it used to measure;
  * pairs/sec below `(1 - tol)` of the baseline for any series
    (default tol 0.15, override with env BENCH_REGRESSION_TOL);
  * a SIMD microkernel that no longer beats the scalar-tiled path: on any
    host whose detected ISA is not "scalar", the Gaussian-sums `tiled_1t`
    series must be at least SIMD_MIN_SPEEDUP (default 1.2) times
    `tiled_1t_scalar`. (The acceptance target on a quiet AVX2 host is
    1.5x; the CI floor is lower to absorb shared-runner noise.)
  * a level-fusion dispatch regression: the fresh run's `fusion` object
    (one batched sparsifier round at n = 4096) must stay within the
    O(log n) bound `dispatches_fused <= 10 * log2_n` and must beat the
    unfused dispatch count by at least 2x — the same contract
    rust/tests/fusion.rs pins, re-checked on the measured series;
  * a frontier-walk dispatch regression: the fresh `walk_fusion` object
    (W = 32 walkers x T = 8 steps at n = 4096 through
    `RandomWalker::walk_batch`) must stay within
    `dispatches_batched <= 10 * t * log2_n` and beat the sequential walk
    dispatch count by at least 2x;
  * an edge-frontier dispatch regression: the fresh `edge_fusion` object
    (one batched triangle estimate, edge_pool = 64 x reps = 8, at
    n = 4096 through `triangle_weight_estimate_batched`) must stay
    within `dispatches_batched <= 10 * log2_n` and beat the sequential
    estimator's dispatch count by at least 2x;
  * a fused-block regression: the fresh `block_fusion` object (LRA-shaped
    row construction through planner-chunked `block_ranged`) must keep
    `peak_rows_chunked <= 64` (the B-row submission cap) and
    `dispatches_chunked <= ceil(s / 64)`;
  * an executor regression: the fresh `executor` object (256 small fused
    `sums_ranged` dispatches at n = 4096 on the persistent sharded worker
    pool vs per-call scoped spawns) must keep `pooled_speedup` at or above
    EXECUTOR_POOL_FLOOR (default 1.0: the pool must at least match
    per-dispatch thread spawning — a within-run ratio, so it is enforced
    on every fresh run regardless of baseline provenance). The object
    also carries the pool busy/queued/steal counters for the trajectory.

Baseline provenance is the `"baseline"` field: `"measured"` (written by
every `cargo bench --bench bench_kde` run) arms the full per-series
comparison; `"bootstrap"` — or the legacy `"provisional": true` — marks a
schema-only committed file and skips only the per-series comparison
(completeness, the SIMD floor and the fusion gate still run against the
fresh numbers). The CI job is self-arming: it caches each run's measured
JSON and compares the next run against the cache when present, so the
committed bootstrap file only matters for the very first run on a fresh
cache key; committing the uploaded `bench-backend-json` artifact upgrades
the in-repo baseline to `"measured"`.

Serving mode (`--serving`) gates `BENCH_serving.json` (written by
`cargo bench --bench bench_serving`) instead:

  * a missing `serving` object — the bench stopped measuring;
  * the coalescing floor, checked **within the fresh run**: solo
    dispatches-per-query must beat coalesced dispatches-per-query by at
    least SERVING_COALESCE_FLOOR (default 2.0) — concurrency that no
    longer amortizes fused submissions is a serving regression no matter
    how the wall clock moved;
  * vs a measured same-ISA baseline: coalesced p99 latency above
    `(1 + tol)` of baseline, or throughput below `(1 - tol)` of baseline
    (same BENCH_REGRESSION_TOL, same bootstrap / ISA-mismatch skip rules
    as the backend series).

Scale mode (`--scale`) gates `BENCH_scale.json` (written by
`cargo bench --bench bench_scale`) instead:

  * a missing or empty `scale.series` — the bench stopped measuring;
  * the ~log n dispatch contract, checked **within the fresh run** at
    every series point: dispatches_per_query of a cold neighbor-sampling
    descent must stay at or under `SCALE_DISPATCH_FACTOR x log2(n)`
    (default factor 4.0 — a solo descent issues two child queries per
    internal level, so ~2 log2(n/leaf_cutoff) is the expected value and
    4 log2(n) the regression ceiling);
  * sub-log growth, when the series has >= 2 points: dispatches-per-query
    growth between the smallest and largest n must stay within
    `SCALE_GROWTH_SLACK` (default 1.5) times the log2(n) growth — a
    super-logarithmic slope means the descent stopped scaling;
  * vs a measured same-ISA baseline: per matching n, dispatches-per-query
    above `SCALE_DPQ_DRIFT` (default 1.25x) of baseline, or batched
    sample latency above `(1 + tol)` of baseline (same
    BENCH_REGRESSION_TOL, same bootstrap / ISA-mismatch skip rules).

Usage: compare_bench.py BASELINE.json FRESH.json
       compare_bench.py --serving BASELINE.json FRESH.json
       compare_bench.py --scale BASELINE.json FRESH.json

Stdlib only — the CI image needs nothing beyond python3.
"""

import json
import os
import sys

KERNELS = ["laplacian", "gaussian", "exponential", "rational_quadratic"]
BACKENDS = ["scalar", "tiled_1t_scalar", "tiled_1t", "tiled_mt"]


def load(path):
    with open(path) as f:
        return json.load(f)


def series(doc):
    out = {}
    for row in doc.get("results", []):
        out[(row["kernel"], row["backend"])] = row
    return out


def bootstrap_skip(baseline, fresh_isa, what):
    """Shared baseline-provenance logic: True when the per-series (or
    per-metric) comparison against `baseline` must be skipped — schema-only
    bootstrap files, the legacy `provisional` flag, or a baseline measured
    on a different ISA (absolute numbers are not comparable across
    heterogeneous shared runners)."""
    if baseline.get("provisional") or baseline.get("baseline") == "bootstrap":
        return True
    base_isa = baseline.get("isa_detected", "unmeasured")
    if base_isa != fresh_isa:
        print(f"baseline ISA ({base_isa}) != fresh ISA ({fresh_isa}): absolute "
              f"{what} is not comparable across hosts; skipping the "
              "baseline comparison (within-run gates still enforced).")
        return True
    return False


def main_serving(baseline, fresh):
    tol = float(os.environ.get("BENCH_REGRESSION_TOL", "0.15"))
    floor = float(os.environ.get("SERVING_COALESCE_FLOOR", "2.0"))
    failures = []

    srv = fresh.get("serving")
    if not srv:
        print("FAIL: fresh run is missing the `serving` object")
        return 1

    dpq = srv["dispatches_per_query"]
    solo_dpq = srv["solo_dispatches_per_query"]
    ratio = solo_dpq / dpq if dpq > 0 else float("inf")
    print(f"serving (n={srv['n']}, {srv['clients']} clients, "
          f"{srv['requests']} requests over {srv['datasets']} datasets):")
    print(f"  coalesced: p50 {srv['p50_us']:.1f}us p99 {srv['p99_us']:.1f}us "
          f"{srv['throughput_qps']:.0f} q/s, {dpq:.4f} dispatches/query "
          f"(mean flush occupancy {srv['mean_flush_occupancy']:.1f})")
    print(f"  solo:      p50 {srv['solo_p50_us']:.1f}us p99 {srv['solo_p99_us']:.1f}us "
          f"{srv['solo_throughput_qps']:.0f} q/s, {solo_dpq:.4f} dispatches/query")
    print(f"  coalescing ratio: {ratio:.2f}x (floor {floor:.2f}x)")

    # Within-run coalescing floor: independent of host speed, so it is
    # enforced on every fresh run, baseline or not.
    if ratio < floor:
        failures.append(
            f"coalescing floor: solo/coalesced dispatches-per-query ratio "
            f"{ratio:.2f}x is below the {floor:.2f}x floor "
            f"({solo_dpq:.4f} vs {dpq:.4f})")

    # Cross-run latency/throughput gate vs a comparable measured baseline.
    base_srv = baseline.get("serving")
    if bootstrap_skip(baseline, fresh.get("isa_detected", "scalar"),
                      "serving latency/throughput") or not base_srv:
        print("no comparable measured serving baseline: skipping the "
              "latency/throughput comparison.")
    else:
        p99_ratio = srv["p99_us"] / base_srv["p99_us"]
        qps_ratio = srv["throughput_qps"] / base_srv["throughput_qps"]
        print(f"  vs baseline: p99 {base_srv['p99_us']:.1f}us -> "
              f"{srv['p99_us']:.1f}us ({p99_ratio:.2f}x), throughput "
              f"{base_srv['throughput_qps']:.0f} -> "
              f"{srv['throughput_qps']:.0f} q/s ({qps_ratio:.2f}x)")
        if p99_ratio > 1.0 + tol:
            failures.append(
                f"serving regression: coalesced p99 at {p99_ratio:.2f}x "
                f"baseline ({base_srv['p99_us']:.1f}us -> {srv['p99_us']:.1f}us, "
                f"tolerance {1.0 + tol:.2f}x)")
        if qps_ratio < 1.0 - tol:
            failures.append(
                f"serving regression: throughput at {qps_ratio:.2f}x baseline "
                f"({base_srv['throughput_qps']:.0f} -> "
                f"{srv['throughput_qps']:.0f} q/s, floor {1.0 - tol:.2f}x)")

    if failures:
        print(f"\nFAIL: {len(failures)} serving-regression issue(s)")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: serving series present, coalescing floor met, "
          "no regression beyond tolerance")
    return 0


def main_scale(baseline, fresh):
    import math

    tol = float(os.environ.get("BENCH_REGRESSION_TOL", "0.15"))
    factor = float(os.environ.get("SCALE_DISPATCH_FACTOR", "4.0"))
    growth_slack = float(os.environ.get("SCALE_GROWTH_SLACK", "1.5"))
    dpq_drift = float(os.environ.get("SCALE_DPQ_DRIFT", "1.25"))
    failures = []

    scale = fresh.get("scale") or {}
    points = scale.get("series") or []
    if not points:
        print("FAIL: fresh run is missing the `scale.series` points")
        return 1
    points = sorted(points, key=lambda p: p["n"])

    # Within-run gates: host-speed independent, enforced on every fresh
    # run regardless of baseline provenance.
    for p in points:
        log2_n = p.get("log2_n") or math.log2(p["n"])
        bound = factor * log2_n
        dpq = p["dispatches_per_query"]
        print(f"scale n={p['n']}: {dpq:.2f} dispatches/query over "
              f"{p['walkers']} cold descents (bound {bound:.1f} = "
              f"{factor} x log2 n), batch mean {p['batch_mean_ns']:.0f} ns")
        if dpq > bound:
            failures.append(
                f"scale regression: n={p['n']} at {dpq:.2f} dispatches/query "
                f"exceeds the ~log n bound {bound:.1f}")
    if len(points) >= 2:
        lo, hi = points[0], points[-1]
        growth = hi["dispatches_per_query"] / lo["dispatches_per_query"]
        log_growth = math.log2(hi["n"]) / math.log2(lo["n"])
        budget = log_growth * growth_slack
        print(f"scale growth n={lo['n']} -> n={hi['n']}: dispatches/query "
              f"x{growth:.2f} (log budget x{budget:.2f})")
        if growth > budget:
            failures.append(
                f"scale regression: dispatches/query grew {growth:.2f}x from "
                f"n={lo['n']} to n={hi['n']}, exceeding the sub-log budget "
                f"{budget:.2f}x")

    # Cross-run drift vs a comparable measured baseline, per matching n.
    base_points = (baseline.get("scale") or {}).get("series") or []
    if bootstrap_skip(baseline, fresh.get("isa_detected", "scalar"),
                      "scale latency/dispatch drift") or not base_points:
        print("no comparable measured scale baseline: skipping the "
              "per-n comparison.")
    else:
        base_by_n = {p["n"]: p for p in base_points}
        for p in points:
            b = base_by_n.get(p["n"])
            if b is None:
                print(f"new scale point (no baseline yet): n={p['n']}")
                continue
            drift = p["dispatches_per_query"] / b["dispatches_per_query"]
            lat = p["batch_mean_ns"] / b["batch_mean_ns"]
            print(f"  vs baseline n={p['n']}: dispatches/query "
                  f"{b['dispatches_per_query']:.2f} -> "
                  f"{p['dispatches_per_query']:.2f} ({drift:.2f}x), "
                  f"batch latency {lat:.2f}x")
            if drift > dpq_drift:
                failures.append(
                    f"scale regression: n={p['n']} dispatches/query at "
                    f"{drift:.2f}x baseline (limit {dpq_drift:.2f}x)")
            if lat > 1.0 + tol:
                failures.append(
                    f"scale regression: n={p['n']} batched sample latency at "
                    f"{lat:.2f}x baseline (tolerance {1.0 + tol:.2f}x)")

    if failures:
        print(f"\nFAIL: {len(failures)} scale-regression issue(s)")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: scale series present, ~log n dispatch contract met, "
          "no drift beyond tolerance")
    return 0


def main(argv):
    serving = "--serving" in argv
    scale = "--scale" in argv
    argv = [a for a in argv if a not in ("--serving", "--scale")]
    if len(argv) != 3:
        print(__doc__)
        return 2
    baseline = load(argv[1])
    fresh = load(argv[2])
    if serving:
        return main_serving(baseline, fresh)
    if scale:
        return main_scale(baseline, fresh)
    tol = float(os.environ.get("BENCH_REGRESSION_TOL", "0.15"))
    min_speedup = float(os.environ.get("SIMD_MIN_SPEEDUP", "1.2"))
    base = series(baseline)
    new = series(fresh)
    failures = []

    # 1. Completeness of the fresh run: the full kernel x backend grid.
    for kernel in KERNELS:
        for backend in BACKENDS:
            if (kernel, backend) not in new:
                failures.append(f"missing series in fresh run: {kernel}/{backend}")

    # 2. SIMD must actually pay on hosts that have it.
    isa = fresh.get("isa_detected", "scalar")
    key_simd = ("gaussian", "tiled_1t")
    key_scalar = ("gaussian", "tiled_1t_scalar")
    if isa != "scalar" and key_simd in new and key_scalar in new:
        ratio = new[key_simd]["pairs_per_sec"] / new[key_scalar]["pairs_per_sec"]
        print(f"SIMD speedup ({isa}, gaussian sums): {ratio:.2f}x "
              f"(floor {min_speedup:.2f}x, acceptance target 1.5x)")
        if ratio < min_speedup:
            failures.append(
                f"SIMD regression: tiled_1t is only {ratio:.2f}x tiled_1t_scalar "
                f"on gaussian sums (floor {min_speedup:.2f}x)")

    # 3. Level fusion must stay O(log n) and actually beat unfused.
    fusion = fresh.get("fusion")
    if fusion:
        fused = fusion["dispatches_fused"]
        unfused = fusion["dispatches_unfused"]
        bound = 10 * fusion["log2_n"]
        print(f"fusion (n={fusion['n']}, t={fusion['t']}): "
              f"{unfused} unfused -> {fused} fused dispatches "
              f"(O(log n) bound {bound})")
        if fused > bound:
            failures.append(
                f"fusion regression: {fused} dispatches per round exceeds "
                f"the O(log n) bound {bound}")
        if fused * 2 > unfused:
            failures.append(
                f"fusion regression: fused round ({fused}) no longer beats "
                f"the unfused round ({unfused}) by 2x")
    else:
        failures.append("fresh run is missing the `fusion` series")

    # 3b. Frontier-batched walks must stay O(T log n) and beat sequential.
    walk = fresh.get("walk_fusion")
    if walk:
        batched = walk["dispatches_batched"]
        sequential = walk["dispatches_sequential"]
        bound = 10 * walk["t"] * walk["log2_n"]
        print(f"walk_fusion (n={walk['n']}, W={walk['walkers']}, t={walk['t']}): "
              f"{sequential} sequential -> {batched} frontier-batched dispatches "
              f"(O(T log n) bound {bound})")
        if batched > bound:
            failures.append(
                f"walk-fusion regression: {batched} dispatches exceeds the "
                f"O(T log n) bound {bound}")
        if batched * 2 > sequential:
            failures.append(
                f"walk-fusion regression: batched walks ({batched}) no longer "
                f"beat sequential walks ({sequential}) by 2x")
    else:
        failures.append("fresh run is missing the `walk_fusion` series")

    # 3b'. Frontier-batched edge sampling must stay O(log n) per estimate
    # and beat the sequential draws.
    edge = fresh.get("edge_fusion")
    if edge:
        batched = edge["dispatches_batched"]
        sequential = edge["dispatches_sequential"]
        bound = 10 * edge["log2_n"]
        print(f"edge_fusion (n={edge['n']}, pool={edge['pool']}, reps={edge['reps']}): "
              f"{sequential} sequential -> {batched} frontier-batched dispatches "
              f"(O(log n) bound {bound})")
        if batched > bound:
            failures.append(
                f"edge-fusion regression: {batched} dispatches exceeds the "
                f"O(log n) bound {bound}")
        if batched * 2 > sequential:
            failures.append(
                f"edge-fusion regression: batched edge draws ({batched}) no "
                f"longer beat sequential draws ({sequential}) by 2x")
    else:
        failures.append("fresh run is missing the `edge_fusion` series")

    # 3c. Fused block rows must keep the planner's chunk shape.
    blk = fresh.get("block_fusion")
    if blk:
        peak = blk["peak_rows_chunked"]
        chunked = blk["dispatches_chunked"]
        chunk_bound = (blk["s"] + 63) // 64
        print(f"block_fusion (n={blk['n']}, s={blk['s']}): "
              f"{chunked} chunked dispatches (bound {chunk_bound}), "
              f"peak chunk {peak} rows (monolithic {blk['peak_rows_monolithic']})")
        if peak > 64:
            failures.append(
                f"block-fusion regression: peak chunk {peak} rows exceeds the "
                f"B = 64 submission cap")
        if chunked > chunk_bound:
            failures.append(
                f"block-fusion regression: {chunked} chunked dispatches exceeds "
                f"ceil(s/64) = {chunk_bound}")
    else:
        failures.append("fresh run is missing the `block_fusion` series")

    # 3d. The persistent worker pool must not lose to per-dispatch thread
    # spawning at the small-fused-dispatch shape. Within-run ratio:
    # enforced on every fresh run, baseline or not.
    pool_floor = float(os.environ.get("EXECUTOR_POOL_FLOOR", "1.0"))
    execu = fresh.get("executor")
    if execu:
        speedup = execu["pooled_speedup"]
        print(f"executor (n={execu['n']}, b={execu['b']}, "
              f"{execu['dispatches']} dispatches, {execu['threads']} threads): "
              f"scoped {execu['dispatch_us_scoped']}us -> pooled "
              f"{execu['dispatch_us_pooled']}us ({speedup:.2f}x, floor "
              f"{pool_floor:.2f}x); pool busy_max {execu['pool_busy_max']} "
              f"queued_max {execu['pool_queued_max']} steals "
              f"{execu['pool_steals']} submitted {execu['pool_submitted']} "
              f"inline {execu['pool_inline_runs']}")
        if speedup < pool_floor:
            failures.append(
                f"executor regression: pooled execution at {speedup:.2f}x "
                f"scoped spawns is below the {pool_floor:.2f}x floor "
                f"({execu['dispatch_us_scoped']}us scoped vs "
                f"{execu['dispatch_us_pooled']}us pooled)")
    else:
        failures.append("fresh run is missing the `executor` series")

    # 4. Per-series throughput vs the baseline. Absolute pairs/sec only
    # compares meaningfully between like hosts: shared CI runners are
    # heterogeneous, so a baseline measured on a different ISA is treated
    # like a bootstrap (the within-run gates above still apply). Same-ISA
    # SKU variance is what BENCH_REGRESSION_TOL absorbs; raise it if a
    # runner pool proves noisier than 15%.
    bootstrap = baseline.get("provisional") or baseline.get("baseline") == "bootstrap"
    base_isa = baseline.get("isa_detected", "unmeasured")
    if not bootstrap and base_isa != isa:
        print(f"baseline ISA ({base_isa}) != fresh ISA ({isa}): absolute "
              "throughput is not comparable across hosts; skipping the "
              "per-series comparison (within-run gates still enforced).")
        bootstrap = True
    if bootstrap:
        print("no comparable measured baseline: skipping per-series "
              "regression comparison.")
        print("fresh series, for committing as the baseline:")
        for (kernel, backend), row in sorted(new.items()):
            print(f"  {kernel:>20s}/{backend:<16s} {row['pairs_per_sec']:.3e} pairs/s "
                  f"[{row.get('isa', '?')}]")
    else:
        print(f"{'series':>38s} {'baseline':>12s} {'fresh':>12s} {'ratio':>7s}")
        for (kernel, backend), brow in sorted(base.items()):
            frow = new.get((kernel, backend))
            if frow is None:
                failures.append(f"series dropped vs baseline: {kernel}/{backend}")
                continue
            ratio = frow["pairs_per_sec"] / brow["pairs_per_sec"]
            flag = ""
            if ratio < 1.0 - tol:
                failures.append(
                    f"regression: {kernel}/{backend} at {ratio:.2f}x baseline "
                    f"({brow['pairs_per_sec']:.3e} -> {frow['pairs_per_sec']:.3e} pairs/s)")
                flag = "  << REGRESSION"
            print(f"{kernel + '/' + backend:>38s} {brow['pairs_per_sec']:>12.3e} "
                  f"{frow['pairs_per_sec']:>12.3e} {ratio:>6.2f}x{flag}")
        for key in sorted(set(new) - set(base)):
            print(f"new series (no baseline yet): {key[0]}/{key[1]}")

    if failures:
        print(f"\nFAIL: {len(failures)} bench-regression issue(s)")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: bench series complete, no regression beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
