//! Quickstart: the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a kernel graph over a synthetic dataset, constructs the KDE
//! primitives (Def. 1.1 / §4), and exercises each building block plus one
//! application (spectral sparsification) with cost accounting.

use std::sync::Arc;

use kde_matrix::apps::sparsify;
use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);

    // 1. A dataset: 2048 points, 16-d, 10 clusters, bandwidth by the
    //    median rule (§3.1) folded into the coordinates.
    let kernel = Kernel::Laplacian;
    let ds = Arc::new(
        dataset::gaussian_mixture(2048, 16, 10, 2.0, 0.5, &mut rng)
            .with_median_bandwidth(kernel, &mut rng),
    );
    println!("dataset: n={} d={} kernel={}", ds.n, ds.d, kernel.name());

    // 2. KDE oracle + §4 primitives. The sampling estimator realizes the
    //    paper's Definition 1.1 contract with eps=0.25 at tau=0.05.
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.25, tau: 0.05 },
        leaf_cutoff: 16,
        seed: 7,
    };
    let prims = Primitives::build(ds.clone(), kernel, &cfg, CpuBackend::new());
    println!(
        "primitives built: {} KDE queries (degree array = n queries, once)",
        prims.kde_queries()
    );

    // 3. Weighted vertex sampling (Alg 4.6).
    let (v, p) = prims.degrees.sample(&mut rng);
    println!("degree-sampled vertex {v} (prob {p:.2e}, deg~{:.2})", prims.degrees.degrees[v]);

    // 4. Weighted neighbor sampling (Alg 4.11) + edge sampling (Alg 4.13).
    let nb = prims.neighbors.sample(v, &mut rng).unwrap();
    println!("neighbor of {v}: {} (descent prob {:.2e})", nb.neighbor, nb.prob);
    let e = prims.edges.sample(&mut rng).unwrap();
    println!("weighted edge: ({}, {}) prob {:.2e}", e.u, e.v, e.prob);

    // 5. Random walk (Alg 4.16).
    let path = prims.walker.trajectory(v, 8, &mut rng);
    println!("8-step walk from {v}: {path:?}");

    // 6. Application: spectral sparsification (Thm 5.3).
    let t = 20 * ds.n;
    let sp = sparsify::sparsify(&prims, t, &mut rng);
    let complete = ds.n * (ds.n - 1) / 2;
    println!(
        "sparsifier: {} distinct edges vs {} complete ({:.0}x smaller), \
         {} KDE queries, {} kernel evals",
        sp.distinct_edges,
        complete,
        complete as f64 / sp.distinct_edges as f64,
        sp.kde_queries,
        sp.kernel_evals
    );
    println!("total KDE queries this session: {}", prims.kde_queries());
    println!("ok");
}
