//! END-TO-END DRIVER (§7 spectral sparsification + clustering, Fig. 4).
//!
//!     make artifacts && cargo run --release --example spectral_clustering
//!
//! Runs the full three-layer stack on the paper's two synthetic datasets:
//!
//!   Pallas/JAX AOT artifacts -> PJRT backend -> KDE oracle -> §4
//!   primitives -> Alg 5.1 sparsifier -> normalized-Laplacian eigenvectors
//!   -> k-means -> labels,
//!
//! and reports the paper's §7.1 metrics: misclassified points, edge/space
//! reduction factor vs the full kernel graph, and eigensolve time on the
//! sparse vs dense graph. Falls back to the CPU backend if artifacts are
//! missing. Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;
use std::time::Instant;

use kde_matrix::apps::{cluster_spectral, sparsify};
use kde_matrix::graph::WGraph;
use kde_matrix::kde::{EstimatorKind, KdeConfig};
use kde_matrix::kernel::{dataset, Dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::pjrt::PjrtBackend;
use kde_matrix::sampling::Primitives;
use kde_matrix::util::rng::Rng;

struct Report {
    name: &'static str,
    n: usize,
    sampled_edges: usize,
    distinct_edges: usize,
    complete_edges: usize,
    misclassified: usize,
    accuracy: f64,
    kde_queries: u64,
    sparse_eig_s: f64,
    dense_eig_s: f64,
}

/// Layer-composition proof: compute the full weighted-degree array through
/// the AOT artifact path (batched `sums` — the artifact's native shape)
/// and check it against the CPU backend. This is the bulk kernel
/// computation every §4 primitive sits on; the sequential tree-descent
/// queries then run on the CPU backend (a 1-point query padded to a 64x
/// batch would waste 63/64 of every PJRT execution — the serving-side fix
/// for that is the coordinator's dynamic batcher, see `kde_server`).
fn verify_pjrt_degrees(ds: &Dataset, kernel: Kernel, pjrt: &Arc<PjrtBackend>) -> bool {
    let cpu = CpuBackend::new();
    let t0 = Instant::now();
    let deg_pjrt = pjrt.sums(kernel, ds.flat(), ds.flat(), ds.d);
    let t_pjrt = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let deg_cpu = cpu.sums(kernel, ds.flat(), ds.flat(), ds.d);
    let t_cpu = t1.elapsed().as_secs_f64();
    let worst = deg_pjrt
        .iter()
        .zip(&deg_cpu)
        .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
        .fold(0.0f64, f64::max);
    println!(
        "  PJRT degree pass: n^2 = {} kernel evals in {:.2}s ({} executions) vs CPU {:.2}s, worst rel dev {:.2e}",
        ds.n * ds.n,
        t_pjrt,
        pjrt.executions(),
        t_cpu,
        worst
    );
    worst < 1e-3
}

fn run_dataset(
    name: &'static str,
    ds: Arc<Dataset>,
    kernel: Kernel,
    t: usize,
    backend: Arc<dyn KernelBackend>,
    rng: &mut Rng,
) -> Report {
    let n = ds.n;
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.3, tau: 0.05 },
        leaf_cutoff: 32,
        seed: 0xF16,
    };
    let prims = Primitives::build(ds.clone(), kernel, &cfg, backend);
    let sp = sparsify::sparsify(&prims, t, rng);

    // Eigensolve timings: sparse vs full graph (the paper's 4.5x / 3.4x).
    let t0 = Instant::now();
    let labels = cluster_spectral::spectral_cluster(&sp.graph, 2, rng);
    let sparse_eig_s = t0.elapsed().as_secs_f64();

    let full = WGraph::complete_kernel_graph(&ds, kernel);
    let t1 = Instant::now();
    let _labels_full = cluster_spectral::spectral_cluster(&full, 2, rng);
    let dense_eig_s = t1.elapsed().as_secs_f64();

    let truth = ds.labels.as_ref().unwrap();
    let accuracy = cluster_spectral::clustering_accuracy(&labels, truth, 2);
    let misclassified = ((1.0 - accuracy) * n as f64).round() as usize;
    Report {
        name,
        n,
        sampled_edges: sp.samples,
        distinct_edges: sp.distinct_edges,
        complete_edges: n * (n - 1) / 2,
        misclassified,
        accuracy,
        kde_queries: sp.kde_queries,
        sparse_eig_s,
        dense_eig_s,
    }
}

fn print_report(r: &Report) {
    println!("--- {} (n = {}) ---", r.name, r.n);
    println!(
        "  sparsifier: {} samples -> {} distinct edges ({:.1}% of complete, {:.0}x space reduction)",
        r.sampled_edges,
        r.distinct_edges,
        100.0 * r.distinct_edges as f64 / r.complete_edges as f64,
        r.complete_edges as f64 / r.distinct_edges as f64,
    );
    println!(
        "  clustering: accuracy {:.2}% ({} / {} misclassified)",
        100.0 * r.accuracy,
        r.misclassified,
        r.n
    );
    println!(
        "  eigensolve: sparse {:.3}s vs dense {:.3}s ({:.1}x speedup)",
        r.sparse_eig_s,
        r.dense_eig_s,
        r.dense_eig_s / r.sparse_eig_s.max(1e-9)
    );
    println!("  kde queries: {}", r.kde_queries);
}

fn main() {
    let mut rng = Rng::new(2022);

    // Paper §7: Nested = 5000 points, 2.5% of edges sampled;
    //           Rings  = 2500 points, 3.3% of edges.
    // Sizes scale down cleanly; pass --full for the paper's exact sizes.
    let full_scale = std::env::args().any(|a| a == "--full");
    let (n_nested, n_rings) = if full_scale { (5000, 2500) } else { (1500, 1000) };

    let nested = Arc::new(dataset::nested(n_nested, &mut rng).scaled(3.0));
    let rings = Arc::new(dataset::rings(n_rings, &mut rng).scaled(6.0));

    // Layer 1+2 proof: run the bulk degree computation through the AOT
    // artifacts on both datasets before the algorithm passes.
    let mut pjrt_ok = false;
    match PjrtBackend::new("artifacts") {
        Ok(pjrt) => {
            println!("PJRT artifact path ({}):", "kde_sums_gaussian.hlo.txt");
            pjrt_ok = verify_pjrt_degrees(&nested, Kernel::Gaussian, &pjrt)
                && verify_pjrt_degrees(&rings, Kernel::Gaussian, &pjrt);
            println!("  parity: {}", if pjrt_ok { "OK" } else { "FAIL" });
        }
        Err(e) => println!("PJRT unavailable ({e}); CPU-only run"),
    }

    // Algorithm passes (scattered 1-point KDE queries -> CPU backend).
    let backend: Arc<dyn KernelBackend> = CpuBackend::new();
    let t_nested = (0.025 * (n_nested * (n_nested - 1) / 2) as f64) as usize;
    let r1 = run_dataset(
        "Nested (Fig. 4a)",
        nested,
        Kernel::Gaussian,
        t_nested,
        backend.clone(),
        &mut rng,
    );
    print_report(&r1);

    let t_rings = (0.033 * (n_rings * (n_rings - 1) / 2) as f64) as usize;
    let r2 = run_dataset(
        "Rings (Fig. 4b)",
        rings,
        Kernel::Gaussian,
        t_rings,
        backend,
        &mut rng,
    );
    print_report(&r2);
    let _ = pjrt_ok;

    // Paper's headline checks (shape, not absolute numbers).
    let ok = r1.accuracy >= 0.99 && r2.accuracy >= 0.99;
    println!(
        "\nheadline: paper reports <= 0.5% misclassified + 41x/30x reduction; \
         we measure {:.1}%/{:.1}% misclassified at {:.0}x/{:.0}x — {}",
        100.0 * (1.0 - r1.accuracy),
        100.0 * (1.0 - r2.accuracy),
        r1.complete_edges as f64 / r1.distinct_edges as f64,
        r2.complete_edges as f64 / r2.distinct_edges as f64,
        if ok { "SHAPE REPRODUCED" } else { "MISMATCH" }
    );
    std::process::exit(if ok { 0 } else { 1 });
}
