//! Low-rank approximation pipeline (Fig. 3 of the paper).
//!
//!     cargo run --release --example lra_pipeline
//!
//! Regenerates, on the MNIST/GloVe synthetic substitutes (DESIGN.md §3):
//!   * Fig. 3a / 3c — rank vs Frobenius error for KDE / IS / SVD,
//!   * Fig. 3b / 3d — true vs estimated squared row norms (CSV scatter),
//!   * the §7.1 cost table — kernel evaluations, space, wall time.
//!
//! CSVs land in `target/figures/`.

use std::sync::Arc;
use std::time::Instant;

use kde_matrix::apps::lra;
use kde_matrix::kde::{EstimatorKind, KdeConfig, KdeCounters};
use kde_matrix::kernel::{dataset, Dataset, Kernel};
use kde_matrix::runtime::backend::CpuBackend;
use kde_matrix::sampling::rownorm::RowNormSampler;
use kde_matrix::util::rng::Rng;

fn run_suite(name: &str, ds: Arc<Dataset>, ranks: &[usize], rng: &mut Rng) {
    let kernel = Kernel::Laplacian; // the paper's §7 kernel
    let n = ds.n;
    println!("=== {name}: n={n} d={} kernel={} ===", ds.d, kernel.name());
    let kmat = lra::materialize_kernel_matrix(&ds, kernel);
    let frob = kmat.frob_norm_sq();

    // Estimator sized for the FKV contract: row-norm sampling only needs
    // constant-factor accuracy (Thm 5.12 tolerates O(1) oversampling), so
    // an eps=0.5 / tau=0.2 sampling oracle (80 kernel evals per query)
    // suffices — this is where the sub-quadratic eval count comes from.
    let cfg = KdeConfig {
        kind: EstimatorKind::Sampling { eps: 0.5, tau: 0.2 },
        leaf_cutoff: 32,
        seed: 0xF3A,
    };

    // Fig. 3b/3d: row-norm scatter (true vs estimated).
    let rn = RowNormSampler::build(&ds, kernel, &cfg, CpuBackend::new(), KdeCounters::new());
    let mut scatter = Vec::with_capacity(n);
    for i in 0..n {
        let truth: f64 = (0..n)
            .map(|j| {
                let v = kmat[(i, j)];
                v * v
            })
            .sum();
        scatter.push(vec![truth, rn.row_norms_sq[i]]);
    }
    std::fs::create_dir_all("target/figures").ok();
    let scatter_path = format!("target/figures/rownorm_scatter_{name}.csv");
    kde_matrix::util::write_csv(&scatter_path, &["true_sq", "estimated_sq"], &scatter).unwrap();
    let worst = scatter
        .iter()
        .map(|r| (r[1] - r[0]).abs() / r[0])
        .fold(0.0f64, f64::max);
    println!("row-norm scatter -> {scatter_path} (worst rel dev {worst:.3})");

    // Fig. 3a/3c: rank vs error for the three methods.
    println!(
        "{:<6} {:>12} {:>12} {:>12} {:>14} {:>12}",
        "rank", "KDE_err", "IS_err", "SVD_err", "KDE_evals", "KDE_floats"
    );
    let mut rows = Vec::new();
    let mut last = (0.0, 0.0, 0.0, 0u64, 0u64, 0.0, 0.0, 0.0);
    for &rank in ranks {
        let be = CpuBackend::new();
        let t0 = Instant::now();
        // rows_factor 10 (paper: 25): our n is 4-10x smaller than the
        // paper's 10^4, so 25x would clamp to the whole matrix at rank 50.
        let r = lra::lra_kde(&ds, kernel, rank, 10, &cfg, be, rng);
        let kde_time = t0.elapsed().as_secs_f64();
        let kde_err = (lra::lra_error(&kmat, &r.v) / frob).sqrt();

        let t1 = Instant::now();
        let v_is = lra::lra_countsketch(&kmat, rank, 4 * rank + 10, rng);
        let is_time = t1.elapsed().as_secs_f64();
        let is_err = (lra::lra_error(&kmat, &v_is) / frob).sqrt();

        let t2 = Instant::now();
        let v_svd = lra::lra_svd(&kmat, rank, 120, rng);
        let svd_time = t2.elapsed().as_secs_f64();
        let svd_err = (lra::lra_error(&kmat, &v_svd) / frob).sqrt();

        println!(
            "{:<6} {:>12.5} {:>12.5} {:>12.5} {:>14} {:>12}",
            rank, kde_err, is_err, svd_err, r.kernel_evals, r.floats_stored
        );
        rows.push(vec![
            rank as f64,
            kde_err,
            is_err,
            svd_err,
            r.kernel_evals as f64,
        ]);
        last = (
            kde_err,
            is_err,
            svd_err,
            r.kernel_evals,
            r.floats_stored,
            kde_time,
            is_time,
            svd_time,
        );
    }
    let curve_path = format!("target/figures/lra_rank_error_{name}.csv");
    kde_matrix::util::write_csv(
        &curve_path,
        &["rank", "kde_err", "is_err", "svd_err", "kde_evals"],
        &rows,
    )
    .unwrap();
    println!("rank-error curve -> {curve_path}");

    // §7.1 cost narrative at the largest rank. (The savings factor grows
    // linearly in n — at the paper's n = 10^4 the same per-rank cost is a
    // 9x+ reduction; print the extrapolation too.)
    let (kde_err, is_err, svd_err, evals, floats, kde_t, is_t, svd_t) = last;
    let full_evals = (n * n) as u64;
    let full_floats = (n * n) as u64;
    let evals_at_10k = evals as f64 / n as f64 * 10_000.0;
    println!("§7.1 costs at rank {}:", ranks.last().unwrap());
    println!(
        "  extrapolated to the paper's n = 10^4: {:.1e} evals vs 10^8 -> {:.0}x fewer",
        evals_at_10k,
        1e8 / evals_at_10k
    );
    println!(
        "  kernel evals : KDE {} vs full {} -> {:.1}x fewer",
        evals,
        full_evals,
        full_evals as f64 / evals as f64
    );
    println!(
        "  space (f32s) : KDE {} vs full {} -> {:.1}x less",
        floats,
        full_floats,
        full_floats as f64 / floats as f64
    );
    println!(
        "  wall time    : KDE {kde_t:.2}s, IS {is_t:.2}s (+materialize), SVD {svd_t:.2}s (+materialize)"
    );
    println!(
        "  errors       : KDE {kde_err:.4} vs IS {is_err:.4} vs SVD {svd_err:.4} (relative Frobenius)"
    );
}

fn main() {
    let mut rng = Rng::new(3);
    let full_scale = std::env::args().any(|a| a == "--full");
    let n = if full_scale { 4000 } else { 1024 };

    // MNIST substitute: 10-cluster mixture, 64-d (matches AOT tile D).
    let mnist_sub = Arc::new(
        dataset::gaussian_mixture(n, 64, 10, 2.0, 0.6, &mut rng)
            .with_median_bandwidth(Kernel::Laplacian, &mut rng),
    );
    run_suite("mnist_sub", mnist_sub, &[1, 2, 5, 10, 20, 35, 50], &mut rng);

    // GloVe substitute: heavy-tailed embeddings.
    let glove_sub = Arc::new(
        dataset::heavy_tailed_mixture(n, 64, 20, &mut rng)
            .with_median_bandwidth(Kernel::Laplacian, &mut rng),
    );
    run_suite("glove_sub", glove_sub, &[1, 2, 4, 6, 8, 10], &mut rng);
}
