//! KDE query service under synthetic open-loop load.
//!
//!     make artifacts && cargo run --release --example kde_server
//!
//! Phase 1 starts the coordinator (router + dynamic batcher + worker
//! pool) over two dataset shards, fires concurrent client threads at it,
//! and reports throughput, latency percentiles and batch occupancy —
//! demonstrating the serving path where the AOT artifact's native batch
//! shape (B = 64) is filled by the batcher rather than padded per query.
//!
//! Phase 2 deliberately overloads the service — a burst far larger than
//! the bounded queue, every request carrying a tight deadline — and
//! reports the failure-model counters next to the latency percentiles:
//! `Overloaded` rejections (backpressure instead of unbounded queueing)
//! and `Timeout` replies (expired requests dropped from the batch plan),
//! with every accepted request still answered exactly once.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kde_matrix::coordinator::{BatcherConfig, KdeService};
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::error::BackendError;
use kde_matrix::runtime::pjrt::PjrtBackend;
use kde_matrix::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(11);
    let backend: Arc<dyn KernelBackend> = match PjrtBackend::new("artifacts") {
        Ok(b) => {
            println!("backend: PJRT (AOT artifacts)");
            b
        }
        Err(e) => {
            println!("backend: CPU ({e})");
            CpuBackend::new()
        }
    };

    let shard0 = Arc::new(dataset::gaussian_mixture(4096, 32, 8, 1.5, 0.5, &mut rng));
    let shard1 = Arc::new(dataset::heavy_tailed_mixture(2048, 32, 6, &mut rng));
    let svc = Arc::new(KdeService::start(
        vec![
            (Kernel::Laplacian, shard0.clone()),
            (Kernel::Gaussian, shard1.clone()),
        ],
        backend,
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(800),
            workers: 4,
            queue_cap: 1024,
        },
    ));

    // ---- Phase 1: well-behaved concurrent load ------------------------
    let clients = 8usize;
    let per_client = 400usize;
    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let svc = svc.clone();
        let s0 = shard0.clone();
        let s1 = shard1.clone();
        let done = done.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(9000 + c as u64);
            // Pipelined client: keep a window of requests outstanding
            // (batched serving only pays off when clients overlap their
            // requests — a strict request/response ping-pong can never
            // fill a batch).
            let window = 32usize;
            let mut outstanding = std::collections::VecDeque::new();
            for r in 0..per_client {
                let shard = rng.below(2);
                let ds = if shard == 0 { &s0 } else { &s1 };
                let i = rng.below(ds.n);
                outstanding.push_back(svc.submit(shard, ds.point(i).to_vec()));
                if outstanding.len() >= window || r + 1 == per_client {
                    while let Some(rx) = outstanding.pop_front() {
                        let ans = rx.recv().expect("dropped").expect("error reply");
                        assert!(ans.is_finite() && ans >= 0.0);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::Relaxed);
    println!("served {total} queries in {wall:.2}s = {:.0} q/s", total as f64 / wall);
    println!("metrics: {}", svc.metrics.summary());
    let occ = svc.metrics.mean_batch_occupancy();
    println!(
        "batch occupancy {occ:.1}/64 — {}",
        if occ > 4.0 { "batching effective" } else { "low concurrency" }
    );

    // ---- Phase 2: deliberate overload with deadlines ------------------
    // One client firing a burst far larger than the bounded queue, each
    // request with a 500us deadline and no pipelining discipline.
    let burst = 20_000usize;
    let deadline = Duration::from_micros(500);
    let mut overloaded = 0u64;
    let mut rxs = Vec::new();
    let mut rng = Rng::new(31);
    let t1 = Instant::now();
    for _ in 0..burst {
        let i = rng.below(shard0.n);
        match svc.try_submit_deadline(0, shard0.point(i).to_vec(), deadline) {
            Ok(rx) => rxs.push(rx),
            Err(BackendError::Overloaded) => overloaded += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let (mut served, mut timeouts) = (0u64, 0u64);
    for rx in rxs {
        match rx.recv().expect("accepted request must be answered") {
            Ok(_) => served += 1,
            Err(BackendError::Timeout) => timeouts += 1,
            Err(BackendError::Overloaded) => overloaded += 1,
            Err(e) => panic!("unexpected reply: {e}"),
        }
    }
    let wall2 = t1.elapsed().as_secs_f64();
    println!(
        "overload burst: {burst} submits in {wall2:.2}s -> served={served} \
         timeouts={timeouts} overloaded={overloaded} \
         (p50={:.0}us p99={:.0}us)",
        svc.metrics.latency_percentile_us(50.0),
        svc.metrics.latency_percentile_us(99.0),
    );
    println!("metrics: {}", svc.metrics.summary());
    assert_eq!(served + timeouts + overloaded, burst as u64, "every request accounted for");
}
