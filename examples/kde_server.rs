//! KDE query service under synthetic open-loop load.
//!
//!     make artifacts && cargo run --release --example kde_server
//!
//! Phase 1 starts the coordinator (router + dynamic batcher + worker
//! pool) over two dataset shards, fires concurrent client threads at it,
//! and reports throughput, latency percentiles and batch occupancy —
//! demonstrating the serving path where the AOT artifact's native batch
//! shape (B = 64) is filled by the batcher rather than padded per query.
//!
//! Phase 2 deliberately overloads the service — a burst far larger than
//! the bounded queue, every request carrying a tight deadline — and
//! reports the failure-model counters next to the latency percentiles:
//! `Overloaded` rejections (backpressure instead of unbounded queueing)
//! and `Timeout` replies (expired requests dropped from the batch plan),
//! with every accepted request still answered exactly once.
//!
//! Phase 3 is the registry/coalescing server (`kde_matrix::server`): the
//! same two datasets registered by *name* into an `OracleRegistry`, a
//! `KdeServer` coalescing concurrent clients' point-index queries (mixed
//! density + seeded neighbor-sample requests) into fused submissions,
//! and the dispatches-per-query printout that shows the amortization —
//! plus a bit-identity spot check against direct solo tree queries.
//! Phase 3 executes on the persistent sharded worker pool (the tiled
//! backend's default route), so the report also prints the pool's
//! busy/queued occupancy next to the latency percentiles.
//!
//! Knobs (all optional, for CI smoke runs and experimentation):
//! `KDE_SERVER_N` (dataset size, default 4096), `KDE_SERVER_CLIENTS`
//! (default 8), `KDE_SERVER_PER_CLIENT` (requests per client, default
//! 400), `KDE_SERVER_BURST` (phase 2 burst size, default 20000).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kde_matrix::coordinator::{BatcherConfig, KdeService};
use kde_matrix::kde::KdeConfig;
use kde_matrix::kernel::{dataset, Kernel};
use kde_matrix::runtime::backend::{CpuBackend, KernelBackend};
use kde_matrix::runtime::error::BackendError;
use kde_matrix::runtime::pjrt::PjrtBackend;
use kde_matrix::runtime::TiledBackend;
use kde_matrix::server::{KdeServer, OracleRegistry, ServerConfig, ServerReply};
use kde_matrix::util::rng::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&v| v > 0)
        .unwrap_or(default)
}

fn main() {
    let n = env_usize("KDE_SERVER_N", 4096);
    let clients = env_usize("KDE_SERVER_CLIENTS", 8);
    let per_client = env_usize("KDE_SERVER_PER_CLIENT", 400);
    let burst = env_usize("KDE_SERVER_BURST", 20_000);
    let mut rng = Rng::new(11);
    let backend: Arc<dyn KernelBackend> = match PjrtBackend::new("artifacts") {
        Ok(b) => {
            println!("backend: PJRT (AOT artifacts)");
            b
        }
        Err(e) => {
            println!("backend: CPU ({e})");
            CpuBackend::new()
        }
    };

    let shard0 = Arc::new(dataset::gaussian_mixture(n, 32, 8, 1.5, 0.5, &mut rng));
    let shard1 = Arc::new(dataset::heavy_tailed_mixture(n / 2, 32, 6, &mut rng));
    let svc = Arc::new(KdeService::start(
        vec![
            (Kernel::Laplacian, shard0.clone()),
            (Kernel::Gaussian, shard1.clone()),
        ],
        backend,
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(800),
            workers: 4,
            queue_cap: 1024,
        },
    ));

    // ---- Phase 1: well-behaved concurrent load ------------------------
    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let svc = svc.clone();
        let s0 = shard0.clone();
        let s1 = shard1.clone();
        let done = done.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(9000 + c as u64);
            // Pipelined client: keep a window of requests outstanding
            // (batched serving only pays off when clients overlap their
            // requests — a strict request/response ping-pong can never
            // fill a batch).
            let window = 32usize;
            let mut outstanding = std::collections::VecDeque::new();
            for r in 0..per_client {
                let shard = rng.below(2);
                let ds = if shard == 0 { &s0 } else { &s1 };
                let i = rng.below(ds.n);
                outstanding.push_back(svc.submit(shard, ds.point(i).to_vec()));
                if outstanding.len() >= window || r + 1 == per_client {
                    while let Some(rx) = outstanding.pop_front() {
                        let ans = rx.recv().expect("dropped").expect("error reply");
                        assert!(ans.is_finite() && ans >= 0.0);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::Relaxed);
    println!("served {total} queries in {wall:.2}s = {:.0} q/s", total as f64 / wall);
    println!("metrics: {}", svc.metrics.summary());
    let occ = svc.metrics.mean_batch_occupancy();
    println!(
        "batch occupancy {occ:.1}/64 — {}",
        if occ > 4.0 { "batching effective" } else { "low concurrency" }
    );

    // ---- Phase 2: deliberate overload with deadlines ------------------
    // One client firing a burst far larger than the bounded queue, each
    // request with a 500us deadline and no pipelining discipline.
    let deadline = Duration::from_micros(500);
    let mut overloaded = 0u64;
    let mut rxs = Vec::new();
    let mut rng = Rng::new(31);
    let t1 = Instant::now();
    for _ in 0..burst {
        let i = rng.below(shard0.n);
        match svc.try_submit_deadline(0, shard0.point(i).to_vec(), deadline) {
            Ok(rx) => rxs.push(rx),
            Err(BackendError::Overloaded) => overloaded += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let (mut served, mut timeouts) = (0u64, 0u64);
    for rx in rxs {
        match rx.recv().expect("accepted request must be answered") {
            Ok(_) => served += 1,
            Err(BackendError::Timeout) => timeouts += 1,
            Err(BackendError::Overloaded) => overloaded += 1,
            Err(e) => panic!("unexpected reply: {e}"),
        }
    }
    let wall2 = t1.elapsed().as_secs_f64();
    println!(
        "overload burst: {burst} submits in {wall2:.2}s -> served={served} \
         timeouts={timeouts} overloaded={overloaded} \
         (p50={:.0}us p99={:.0}us)",
        svc.metrics.latency_percentile_us(50.0),
        svc.metrics.latency_percentile_us(99.0),
    );
    println!("metrics: {}", svc.metrics.summary());
    assert_eq!(served + timeouts + overloaded, burst as u64, "every request accounted for");

    // ---- Phase 3: registry + cross-request coalescing server ----------
    // The same two datasets, now registered by NAME: each is built once
    // into a shared multi-level tree, and the KdeServer coalesces all
    // clients' point-index queries per dataset into fused submissions.
    // A fresh TiledBackend so (a) its dispatch counter cleanly reads
    // "fused submissions for this phase" and (b) those dispatches run on
    // the persistent sharded worker pool, whose occupancy counters are
    // reported below next to the latency percentiles.
    let be = TiledBackend::new();
    let registry = OracleRegistry::new(be.clone());
    registry.register("web", shard0.clone(), Kernel::Laplacian, &KdeConfig::exact());
    registry.register("tail", shard1.clone(), Kernel::Gaussian, &KdeConfig::exact());
    println!("\nregistry: {:?} registered", registry.names());
    let server = KdeServer::start(
        registry.clone(),
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(300),
            queue_cap: 4096,
        },
    );
    let dispatch_base = be.calls();
    let t2 = Instant::now();
    let densities = Arc::new(AtomicU64::new(0));
    let neighbors = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            let densities = densities.clone();
            let neighbors = neighbors.clone();
            let (n0, n1) = (shard0.n, shard1.n);
            s.spawn(move || {
                let mut inflight = Vec::with_capacity(per_client);
                for r in 0..per_client {
                    if r % 4 == 3 {
                        // Every 4th request: a seeded neighbor sample from
                        // "tail" — the seed alone fixes the answer, so the
                        // coalesced reply equals a solo draw bit for bit.
                        let source = (c * per_client + r) % n1;
                        let seed = 0x5EED_0000 + (c * per_client + r) as u64;
                        inflight.push((
                            false,
                            server.try_submit_neighbor("tail", source, seed).expect("submit"),
                        ));
                    } else {
                        // Distinct per-client index ranges: every density
                        // query is a cold memo-cache miss, so the dispatch
                        // counter below reads fused submissions per cold
                        // query.
                        let point = (c * per_client + r) % n0;
                        inflight.push((
                            true,
                            server.try_submit_density("web", point).expect("submit"),
                        ));
                    }
                }
                for (is_density, rx) in inflight {
                    match rx.recv().expect("server replies").expect("typed reply") {
                        ServerReply::Density(v) => {
                            assert!(is_density && v.is_finite() && v >= 0.0);
                            densities.fetch_add(1, Ordering::Relaxed);
                        }
                        ServerReply::Neighbor(ns) => {
                            assert!(!is_density);
                            if let Some(ns) = ns {
                                assert!(ns.neighbor < n1 && ns.prob > 0.0);
                            }
                            neighbors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall3 = t2.elapsed().as_secs_f64();
    let dispatches = be.calls() - dispatch_base;
    let served3 = densities.load(Ordering::Relaxed) + neighbors.load(Ordering::Relaxed);
    println!(
        "coalescing server: {served3} requests ({} density + {} neighbor) in {wall3:.2}s \
         = {:.0} q/s",
        densities.load(Ordering::Relaxed),
        neighbors.load(Ordering::Relaxed),
        served3 as f64 / wall3
    );
    println!(
        "dispatches: {dispatches} fused submissions / {} queries = {:.3} dispatches/query \
         (solo = 1 per cold query; mean flush occupancy {:.1})",
        served3,
        dispatches as f64 / served3 as f64,
        server.metrics.mean_batch_occupancy()
    );
    // Pool occupancy next to the percentiles: busy/queued are live gauges
    // (0 once the load drains), busy_max/queued_max/steals show how hard
    // the pool ran during the phase. The pool is lazy — `None` means every
    // dispatch ran inline (single worker or single-chunk shapes).
    let pool = match be.pool_metrics() {
        Some(m) => format!("pool {}", m.summary()),
        None => "pool inline (never spun up)".to_string(),
    };
    println!(
        "latency: p50={:.0}us p99={:.0}us | {pool} | metrics: {}",
        server.metrics.latency_percentile_us(50.0),
        server.metrics.latency_percentile_us(99.0),
        server.metrics.summary()
    );

    // Bit-identity spot check: a few served densities and one neighbor
    // draw must equal direct solo queries on the registered trees.
    let web = registry.get("web").expect("registered");
    for i in [0usize, 1, 2] {
        let solo = web.tree.query_point(web.tree.root(), i);
        let served = server.try_query_density("web", i).expect("query");
        assert_eq!(served.to_bits(), solo.to_bits(), "coalesced != solo for point {i}");
    }
    let tail = registry.get("tail").expect("registered");
    let solo_ns = tail.sampler.sample(0, &mut Rng::new(0x5EED_0000 + 3));
    let served_ns = server.try_sample_neighbor("tail", 0, 0x5EED_0000 + 3).expect("sample");
    assert_eq!(
        served_ns.map(|s| (s.neighbor, s.prob.to_bits())),
        solo_ns.map(|s| (s.neighbor, s.prob.to_bits())),
        "coalesced neighbor sample != solo draw on the same seed"
    );
    println!("bit-identity spot check vs solo tree queries: ok");
    server.shutdown();
}
